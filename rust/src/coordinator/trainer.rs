//! The config-driven trainer: engine-agnostic training loop with
//! streaming gradient application, per-step memory/time accounting and
//! JSONL metric logging — the Fig.-4 harness and the e2e example's core.

use std::path::Path;

use crate::autodiff::GradEngine;
use crate::coordinator::data::TextureDataset;
use crate::coordinator::optimizer::Optimizer;
use crate::model::Network;
use crate::nn::SoftmaxCrossEntropy;
use crate::runtime::pool;
use crate::tensor::tracker;
use crate::util::json::Json;
use crate::util::logging::JsonlWriter;
use crate::util::{Rng, Timer};

/// Summary of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps: usize,
    pub final_loss: f32,
    pub train_accuracy: f32,
    pub test_accuracy: f32,
    pub loss_curve: Vec<f32>,
    pub peak_mem_bytes: usize,
    pub total_time_s: f64,
}

/// Classification trainer binding a network, engine, optimizer and data.
pub struct Trainer<'a> {
    pub net: &'a mut Network,
    pub engine: &'a dyn GradEngine,
    pub optimizer: Optimizer,
    pub log_every: usize,
}

impl<'a> Trainer<'a> {
    pub fn new(
        net: &'a mut Network,
        engine: &'a dyn GradEngine,
        optimizer: Optimizer,
    ) -> Trainer<'a> {
        Trainer {
            net,
            engine,
            optimizer,
            log_every: 10,
        }
    }

    /// Train for `steps` mini-batch steps, logging to `metrics` (JSONL)
    /// when given.
    pub fn train(
        &mut self,
        train: &TextureDataset,
        test: &TextureDataset,
        batch: usize,
        steps: usize,
        rng: &mut Rng,
        metrics: Option<&Path>,
    ) -> anyhow::Result<TrainReport> {
        let mut writer = match metrics {
            Some(p) => Some(JsonlWriter::create(p)?),
            None => None,
        };
        let mut loss_curve = Vec::with_capacity(steps);
        let mut peak_mem = 0usize;
        let timer = Timer::start();
        let mut batches: Vec<Vec<usize>> = Vec::new();
        let mut step = 0usize;
        while step < steps {
            if batches.is_empty() {
                batches = train.epoch_batches(batch, rng);
                batches.reverse(); // pop() takes them in epoch order
            }
            let idx = batches.pop().expect("non-empty epoch");
            let (x, labels) = train.batch(&idx);
            let loss = SoftmaxCrossEntropy::new(labels);

            self.optimizer.begin_step();
            let step_timer = Timer::start();
            let pool0 = pool::stats();
            // The engine streams gradients internally; here they are
            // collected so the (aliasing-safe) apply happens after the
            // engine releases the network. The figure benches measure the
            // paper's grad-free accounting with a dropping sink instead.
            let (result, prof) = {
                let net = &*self.net;
                let engine = self.engine;
                tracker::measure(|| engine.compute(net, &x, &loss))
            };
            let pool1 = pool::stats();
            let result = result?;
            for (li, grads) in result.grads.iter().enumerate() {
                if !grads.is_empty() {
                    self.optimizer.apply_layer(self.net, li, grads);
                }
            }
            let loss_val = result.loss;
            peak_mem = peak_mem.max(prof.peak_extra_bytes);
            loss_curve.push(loss_val);
            step += 1;

            if let Some(w) = writer.as_mut() {
                if step % self.log_every == 0 || step == steps {
                    w.write(&Json::from_pairs(vec![
                        ("step", step.into()),
                        ("loss", (loss_val as f64).into()),
                        ("peak_mem_bytes", prof.peak_extra_bytes.into()),
                        ("allocs", prof.allocs.into()),
                        ("step_time_s", step_timer.elapsed_s().into()),
                        ("engine", self.engine.name().as_str().into()),
                        ("threads", pool::threads().into()),
                        // Pool-lifecycle deltas for this step: parallel
                        // regions dispatched, worker wake/park round
                        // trips, plus the (monotone) team size — the
                        // §Perf signal that region dispatch stays cheap.
                        ("pool_regions", (pool1.regions - pool0.regions).into()),
                        ("pool_wakes", (pool1.wakes - pool0.wakes).into()),
                        ("pool_parks", (pool1.parks - pool0.parks).into()),
                        ("pool_workers", pool1.workers_spawned.into()),
                    ]))?;
                }
            }
        }
        if let Some(w) = writer.as_mut() {
            w.flush()?;
        }

        let train_accuracy = self.evaluate(train, batch);
        let test_accuracy = self.evaluate(test, batch);
        Ok(TrainReport {
            steps,
            final_loss: *loss_curve.last().unwrap_or(&f32::NAN),
            train_accuracy,
            test_accuracy,
            loss_curve,
            peak_mem_bytes: peak_mem,
            total_time_s: timer.elapsed_s(),
        })
    }

    /// Mean accuracy over a dataset.
    pub fn evaluate(&self, data: &TextureDataset, batch: usize) -> f32 {
        if data.is_empty() {
            return f32::NAN;
        }
        let mut correct = 0.0;
        let mut count = 0usize;
        let idx: Vec<usize> = (0..data.len()).collect();
        for chunk in idx.chunks(batch) {
            let (x, labels) = data.batch(chunk);
            let y = self.net.forward(&x);
            let loss = SoftmaxCrossEntropy::new(labels);
            correct += loss.accuracy(&y) * chunk.len() as f32;
            count += chunk.len();
        }
        correct / count as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{Backprop, Moonwalk, MoonwalkOpts};
    use crate::coordinator::data::SyntheticSpec;
    use crate::coordinator::optimizer::OptimizerKind;
    use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};

    fn tiny_setup(seed: u64) -> (Network, TextureDataset, TextureDataset) {
        let mut rng = Rng::new(seed);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: 2,
            channels: 6,
            cin: 2,
            classes: 3,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let data = TextureDataset::generate(
            SyntheticSpec {
                hw: 16,
                cin: 2,
                classes: 3,
                noise: 0.15,
                seed,
            },
            60,
        );
        let (train, test) = data.split(0.2);
        (net, train, test)
    }

    #[test]
    fn training_reduces_loss_backprop() {
        let (mut net, train, test) = tiny_setup(0);
        let opt = Optimizer::new(OptimizerKind::Adam, 2e-3, &net, true);
        let engine = Backprop;
        let mut t = Trainer::new(&mut net, &engine, opt);
        let mut rng = Rng::new(1);
        let rep = t.train(&train, &test, 4, 30, &mut rng, None).unwrap();
        let early: f32 = rep.loss_curve[..5].iter().sum::<f32>() / 5.0;
        let late: f32 = rep.loss_curve[rep.loss_curve.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(late < early, "loss should fall: {early} -> {late}");
    }

    #[test]
    fn training_with_moonwalk_engine_works() {
        let (mut net, train, test) = tiny_setup(2);
        let opt = Optimizer::new(OptimizerKind::Adam, 2e-3, &net, true);
        let engine = Moonwalk::new(MoonwalkOpts::default());
        let mut t = Trainer::new(&mut net, &engine, opt);
        let mut rng = Rng::new(3);
        let rep = t.train(&train, &test, 4, 20, &mut rng, None).unwrap();
        assert!(rep.final_loss.is_finite());
        assert!(rep.peak_mem_bytes > 0);
    }

    #[test]
    fn metrics_file_written() {
        let (mut net, train, test) = tiny_setup(4);
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
        let engine = Backprop;
        let mut t = Trainer::new(&mut net, &engine, opt);
        t.log_every = 2;
        let dir = std::env::temp_dir().join("moonwalk_trainer_test");
        let path = dir.join("metrics.jsonl");
        let mut rng = Rng::new(5);
        t.train(&train, &test, 4, 6, &mut rng, Some(&path)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() >= 3);
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert!(first.get("loss").as_f64().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
