//! Sweep driver shared by the figure benches and example binaries: run a
//! set of gradient engines across a depth (or block-size) grid, measuring
//! wall-clock and peak extra memory under the paper's grad-free
//! accounting (sink drops gradients immediately; Table 1 §11).

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::Loss;
use crate::tensor::{tracker, Tensor};
use crate::util::timer;

/// One measured cell of a sweep.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub engine: String,
    pub depth: usize,
    pub param: usize,
    pub peak_mem_bytes: usize,
    pub median_time_s: f64,
    pub loss: f32,
}

/// Measure one engine on one network: peak extra bytes (grad-free
/// accounting) and median wall-clock over `iters` runs.
pub fn measure_engine(
    engine: &dyn GradEngine,
    net: &Network,
    x0: &Tensor,
    loss: &dyn Loss,
    warmup: usize,
    iters: usize,
) -> anyhow::Result<(usize, f64, f32)> {
    // `warmup` unmeasured runs first. Besides the usual cache warming,
    // these populate process-global state (the scratch arena's pooled
    // buffers, the lazily-resolved worker pool) so per-engine peaks are
    // order-independent; `warmup = 0` deliberately measures a cold
    // start, arena misses included.
    for _ in 0..warmup {
        engine.compute_streaming(net, x0, loss, &mut |_, grads| drop(grads))?;
    }

    // Memory profile: one run under the measurement lock.
    let (res, prof) = tracker::measure(|| {
        engine.compute_streaming(net, x0, loss, &mut |_, grads| drop(grads))
    });
    let loss_val = res?;

    // Timing: median over iters; the memory run above doubles as the
    // timing warm-up, so none is repeated here.
    let stats = timer::bench(0, iters, || {
        engine
            .compute_streaming(net, x0, loss, &mut |_, grads| drop(grads))
            .expect("engine already validated");
    });
    Ok((prof.peak_extra_bytes, stats.median, loss_val))
}

/// Format a sweep as an aligned text table (what the benches print).
pub fn format_table(title: &str, rows: &[SweepRow]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    let _ = writeln!(
        out,
        "{:<24} {:>6} {:>7} {:>14} {:>12} {:>12}",
        "engine", "depth", "param", "peak_mem", "median_ms", "loss"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<24} {:>6} {:>7} {:>14} {:>12.2} {:>12.4}",
            r.engine,
            r.depth,
            r.param,
            tracker::fmt_bytes(r.peak_mem_bytes),
            r.median_time_s * 1e3,
            r.loss
        );
    }
    out
}

/// Serialize rows as CSV (benches drop these next to the printed table).
pub fn to_csv(rows: &[SweepRow]) -> String {
    let mut out = String::from("engine,depth,param,peak_mem_bytes,median_time_s,loss\n");
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{},{}\n",
            r.engine, r.depth, r.param, r.peak_mem_bytes, r.median_time_s, r.loss
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};
    use crate::nn::MeanLoss;
    use crate::util::Rng;

    #[test]
    fn measure_engine_returns_sane_values() {
        let mut rng = Rng::new(0);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: 2,
            channels: 4,
            cin: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[1, 16, 16, 2], 1.0, &mut rng);
        let (mem, time, loss) =
            measure_engine(&Backprop, &net, &x, &MeanLoss, 1, 3).unwrap();
        assert!(mem > 0);
        assert!(time > 0.0);
        assert!(loss.is_finite());
    }

    #[test]
    fn table_and_csv_contain_rows() {
        let rows = vec![SweepRow {
            engine: "backprop".into(),
            depth: 3,
            param: 0,
            peak_mem_bytes: 1 << 20,
            median_time_s: 0.01,
            loss: 0.5,
        }];
        let t = format_table("test", &rows);
        assert!(t.contains("backprop"));
        assert!(t.contains("MiB"));
        let csv = to_csv(&rows);
        assert_eq!(csv.lines().count(), 2);
    }
}
