//! First-order optimizers operating on a [`Network`]'s parameters.
//!
//! Gradients arrive *streamed* per layer (the `GradEngine` sink), so the
//! optimizer keeps per-layer state and can apply updates the moment a
//! layer's gradient is ready — the §4.3 "gradients … need not be stored
//! simultaneously" property. Constrained training re-projects each layer
//! onto the submersive set right after its update (§6.4).

use crate::model::Network;
use crate::tensor::Tensor;

/// Supported update rules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Momentum,
    Adam,
}

impl OptimizerKind {
    pub fn parse(name: &str) -> anyhow::Result<OptimizerKind> {
        Ok(match name {
            "sgd" => OptimizerKind::Sgd,
            "momentum" => OptimizerKind::Momentum,
            "adam" => OptimizerKind::Adam,
            other => anyhow::bail!("unknown optimizer `{other}`"),
        })
    }
}

/// Per-parameter optimizer state.
#[derive(Default)]
struct Slot {
    m: Vec<f32>,
    v: Vec<f32>,
}

/// A streaming optimizer bound to a network's layer/param structure.
pub struct Optimizer {
    pub kind: OptimizerKind,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub momentum: f32,
    /// Adam step counter (per whole-network step).
    step: usize,
    state: Vec<Vec<Slot>>,
    /// Re-project layers onto the submersive constraint set after update.
    pub project: bool,
}

impl Optimizer {
    pub fn new(kind: OptimizerKind, lr: f32, net: &Network, project: bool) -> Optimizer {
        let state = net
            .layers
            .iter()
            .map(|l| l.params().iter().map(|_| Slot::default()).collect())
            .collect();
        Optimizer {
            kind,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            momentum: 0.9,
            step: 0,
            state,
            project,
        }
    }

    /// Mark the beginning of a new optimization step (Adam bias
    /// correction counts whole steps, not per-layer applications).
    pub fn begin_step(&mut self) {
        self.step += 1;
    }

    /// Apply one layer's gradients to the network; called from the
    /// engine's streaming sink.
    pub fn apply_layer(&mut self, net: &mut Network, layer: usize, grads: &[Tensor]) {
        debug_assert!(self.step > 0, "begin_step() before apply_layer()");
        let kind = self.kind;
        let (lr, b1, b2, eps, mu) = (self.lr, self.beta1, self.beta2, self.eps, self.momentum);
        let t = self.step as f32;
        let slots = &mut self.state[layer];
        let mut params = net.layers[layer].params_mut();
        assert_eq!(params.len(), grads.len(), "grad/param arity mismatch");
        for (pi, grad) in grads.iter().enumerate() {
            let p = params[pi].data_mut();
            let g = grad.data();
            assert_eq!(p.len(), g.len());
            match kind {
                OptimizerKind::Sgd => {
                    for (pv, gv) in p.iter_mut().zip(g) {
                        *pv -= lr * gv;
                    }
                }
                OptimizerKind::Momentum => {
                    let slot = &mut slots[pi];
                    if slot.m.is_empty() {
                        slot.m = vec![0.0; p.len()];
                    }
                    for ((pv, gv), mv) in p.iter_mut().zip(g).zip(slot.m.iter_mut()) {
                        *mv = mu * *mv + gv;
                        *pv -= lr * *mv;
                    }
                }
                OptimizerKind::Adam => {
                    let slot = &mut slots[pi];
                    if slot.m.is_empty() {
                        slot.m = vec![0.0; p.len()];
                        slot.v = vec![0.0; p.len()];
                    }
                    let bc1 = 1.0 - b1.powf(t);
                    let bc2 = 1.0 - b2.powf(t);
                    for (i, (pv, gv)) in p.iter_mut().zip(g).enumerate() {
                        slot.m[i] = b1 * slot.m[i] + (1.0 - b1) * gv;
                        slot.v[i] = b2 * slot.v[i] + (1.0 - b2) * gv * gv;
                        let mhat = slot.m[i] / bc1;
                        let vhat = slot.v[i] / bc2;
                        *pv -= lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
        }
        drop(params);
        if self.project {
            net.layers[layer].project_submersive();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{Backprop, GradEngine};
    use crate::model::build_mlp;
    use crate::nn::{Loss, MeanLoss};
    use crate::util::Rng;

    fn quadratic_progress(kind: OptimizerKind) -> (f32, f32) {
        // Minimize mean of outputs of a tiny MLP — loss should decrease.
        let mut rng = Rng::new(0);
        let mut net = build_mlp(&[4, 4, 2], 0.1, &mut rng);
        let x = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let mut opt = Optimizer::new(kind, 0.05, &net, false);
        let loss0 = MeanLoss.value(&net.forward(&x));
        for _ in 0..30 {
            opt.begin_step();
            let r = Backprop.compute(&net, &x, &MeanLoss).unwrap();
            for (li, g) in r.grads.iter().enumerate() {
                if !g.is_empty() {
                    opt.apply_layer(&mut net, li, g);
                }
            }
        }
        (loss0, MeanLoss.value(&net.forward(&x)))
    }

    #[test]
    fn sgd_decreases_loss() {
        let (a, b) = quadratic_progress(OptimizerKind::Sgd);
        assert!(b < a, "sgd: {b} !< {a}");
    }

    #[test]
    fn momentum_decreases_loss() {
        let (a, b) = quadratic_progress(OptimizerKind::Momentum);
        assert!(b < a, "momentum: {b} !< {a}");
    }

    #[test]
    fn adam_decreases_loss() {
        let (a, b) = quadratic_progress(OptimizerKind::Adam);
        assert!(b < a, "adam: {b} !< {a}");
    }

    #[test]
    fn projection_keeps_submersive() {
        use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};
        let mut rng = Rng::new(1);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 8,
            depth: 1,
            channels: 3,
            cin: 2,
            ..Default::default()
        };
        let mut net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[1, 8, 8, 2], 1.0, &mut rng);
        let mut opt = Optimizer::new(OptimizerKind::Sgd, 0.5, &net, true);
        for _ in 0..5 {
            opt.begin_step();
            let r = Backprop.compute(&net, &x, &MeanLoss).unwrap();
            for (li, g) in r.grads.iter().enumerate() {
                if !g.is_empty() {
                    opt.apply_layer(&mut net, li, g);
                }
            }
            assert!(
                net.audit()[1..].iter().all(|s| s.is_submersive()),
                "projection must hold after every step"
            );
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(OptimizerKind::parse("adam").unwrap(), OptimizerKind::Adam);
        assert!(OptimizerKind::parse("lion").is_err());
    }
}
