//! The training coordinator (L3): optimizers, synthetic data pipelines,
//! the config-driven trainer with JSONL metrics, and the sweep driver the
//! benches and examples share. Python never runs on any of these paths.

pub mod data;
pub mod optimizer;
pub mod sweep;
pub mod trainer;

pub use data::{SyntheticSpec, TextureDataset};
pub use optimizer::{Optimizer, OptimizerKind};
pub use trainer::{TrainReport, Trainer};
