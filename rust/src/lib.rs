//! # Moonwalk: Inverse-Forward Differentiation
//!
//! A reproduction of *"Moonwalk: Inverse-Forward Differentiation"*
//! (Krylov, Karamzade, Fox), built as a three-layer Rust + JAX + Pallas
//! stack. The crate provides:
//!
//! * [`tensor`] — a small dense-tensor library with a global allocation
//!   tracker that measures peak live bytes (the reproduction's analogue of
//!   `jax.device.memory_stats()` on the paper's RTX 3090).
//! * [`nn`] — a layer library with *submersive* parameterisations
//!   (paper Lemma 1) where every layer exposes four differential operators:
//!   `forward`, `vjp_input`, `vjp_params` and the paper's novel
//!   **`vijp`** (vector-inverse-Jacobian product).
//! * [`autodiff`] — nine interchangeable gradient engines: Backprop,
//!   checkpointed Backprop, true forward mode, projected forward gradients,
//!   reversible backprop, **mixed-mode Moonwalk**, **pure-forward
//!   Moonwalk**, Moonwalk + activation checkpointing, and Moonwalk with
//!   **fragmental gradient checkpointing** (paper §5.1).
//! * [`memsim`] — the analytic time/memory model of the paper's Table 1
//!   plus a memory-budget planner that picks an engine for a budget.
//! * [`plan`] — the **budgeted per-layer execution planner**: a
//!   calibration probe measures each layer's residual tiers on the
//!   concrete input shape, a Pareto DP assigns every layer a strategy
//!   (`vijp` / fragmental capture with a searched block size / full or
//!   minimal cotangent residual) minimizing predicted step time under a
//!   peak-bytes budget, and [`autodiff::PlannedEngine`] executes the
//!   compiled mix in the Moonwalk Phase I–III structure (`--budget` /
//!   `MOONWALK_BUDGET`, `--engine planned`).
//! * [`coordinator`] — a config-driven trainer (optimizers, synthetic data
//!   pipelines, JSONL metrics, sweeps).
//! * [`distributed`] — data-parallel replica sharding behind pluggable
//!   **transports**: a `ReplicaGroup` runs one gradient engine per
//!   replica over disjoint sub-batches and all-reduces gradients **per
//!   layer, streamed** (replica-ordered and deterministic — fixed
//!   replica count ⇒ bit-identical results), so the paper's
//!   streamed-gradient property (§4.3) survives sharding. Where the
//!   replicas execute is a `distributed::transport::Transport`:
//!   in-process on the worker pool (default) or one worker
//!   **subprocess** per replica over unix-domain sockets
//!   (`--transport unix`), bit-identical to each other at equal replica
//!   counts. `distributed::pipeline` adds the async double-buffered
//!   data loader with splittable `seed ⊕ epoch ⊕ shard` RNG streams
//!   (replicas = 1 and replicas = N draw identical global batches).
//!   `--replicas` / `MOONWALK_REPLICAS` select the replica count; the
//!   transport seam is where multi-backend (native / PJRT) dispatch
//!   plugs in next.
//! * [`runtime`] — the persistent worker-thread pool behind the parallel
//!   tensor runtime (`runtime::pool`, `--threads`; workers park between
//!   regions, so even sub-100 µs kernels amortize dispatch), plus a PJRT
//!   client (gated
//!   behind the `xla` feature) that loads the AOT artifacts produced by
//!   `python/compile/aot.py` (JAX/Pallas → HLO text) and executes them
//!   from the Rust hot path; Python never runs at training time.
//! * [`obs`] — zero-cost-off span tracing (`--trace out.trace.json`
//!   emits a Perfetto-loadable Chrome trace of the Phase I–III / pool /
//!   arena / transport timeline, with per-span memory samples) plus a
//!   typed metrics registry whose `snapshot()` feeds the trainer JSONL
//!   stream and `BENCH_perf_ops.json`.
//! * [`util`] / [`cli`] — in-tree substrates (JSON codec, PCG64 RNG, CLI
//!   parser, timing harness) since the offline build has no access to
//!   serde/clap/criterion/rand.
//!
//! # Module tour
//!
//! Data flows bottom-up: [`tensor`] kernels are scheduled by
//! [`runtime::pool`]; [`nn`] layers compose them into the four
//! differential operators; [`autodiff`] engines sequence those operators
//! into gradient strategies; [`plan`] compiles a *per-layer* strategy
//! mix under a byte budget for [`autodiff::PlannedEngine`] to execute;
//! [`model`] stacks layers into networks;
//! [`coordinator`] trains them; [`distributed`] replicates the whole
//! thing across pool shares or worker subprocesses. `docs/ARCHITECTURE.md`
//! is the narrative version of this map — paper equation → module — and
//! names the three runtime invariant contracts (deterministic
//! partitioning, tracker-invisible prefetch, replica-ordered reduction)
//! with the tests that enforce each. `docs/BENCH_SCHEMA.md` documents
//! every field of the `BENCH_perf_ops.json` the tier-1 perf smoke emits.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod autodiff;
pub mod cli;
pub mod coordinator;
pub mod distributed;
pub mod memsim;
pub mod model;
pub mod nn;
pub mod obs;
pub mod plan;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use tensor::Tensor;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
