//! Analytic memory/time model of the paper's Table 1 (§11 "Complexity
//! Analysis") and a **memory-budget planner** built on it: given a byte
//! budget, pick the cheapest-in-time gradient engine that fits.
//!
//! Per-layer quantities follow the paper's definitions: `Mx` is the
//! memory needed to compute `∂x_i/∂x_{i−1}` (our Minimal residual), `Mθ`
//! the *added* memory to also compute `∂x_i/∂θ_i` (Full − Minimal), `n`
//! the activation size and `d` the parameter count. The model predicts
//! *extra* bytes to compute gradients, excluding parameters and the
//! gradients themselves — exactly Table 1's accounting.

use crate::model::Network;
use crate::nn::{residual_bytes, ResidualKind, Submersivity};
use crate::tensor::Tensor;

/// Per-layer cost profile (bytes / counts for one concrete input shape).
#[derive(Clone, Debug)]
pub struct LayerCost {
    pub name: String,
    /// Minimal-residual bytes (paper's `Mx`).
    pub mx: usize,
    /// Additional Full-residual bytes (paper's `Mθ`).
    pub m_theta: usize,
    /// Activation (output) bytes (`n`, in bytes).
    pub act_bytes: usize,
    /// Input bytes to the layer.
    pub in_bytes: usize,
    /// Parameter count (`d`).
    pub d_params: usize,
    /// Forward FLOPs.
    pub flops: f64,
    /// Is the layer's Jacobian right-invertible (`vijp` available)?
    /// Reversible blocks (`nn::reversible`) report `true` regardless of
    /// their inner branches: the coupling structure makes the composite
    /// Jacobian unit-triangular, hence exactly invertible.
    pub submersive: bool,
    pub fragmental_ok: bool,
    /// Does the layer's vijp avoid the sequential spatial wavefront
    /// (`Submersivity::Submersive { fast_path }`)? The per-layer planner
    /// (`crate::plan`) charges wavefront vijps extra time.
    pub fast_vijp: bool,
}

/// Bytes of the §5.1 fragmental cotangent checkpoint for a layer whose
/// output cotangent occupies `act_bytes`: the first `k − 1` slices of
/// each block of `block` positions. The analytic twin of
/// `Layer::fragment_capture`'s storage (which additionally rounds the
/// tail block up — the calibration probe in `crate::plan` measures that
/// exactly).
pub fn fragment_checkpoint_bytes(act_bytes: usize, block: usize, k: usize) -> usize {
    act_bytes * (k.saturating_sub(1)) / block.max(1)
}

/// Profile a network on a concrete input shape by running each layer's
/// forward once per residual tier (cheap; used at plan time, not in the
/// training hot path).
pub fn profile(net: &Network, in_shape: &[usize]) -> anyhow::Result<Vec<LayerCost>> {
    let mut costs = Vec::with_capacity(net.depth());
    let mut x = Tensor::zeros(in_shape);
    for layer in &net.layers {
        let (_, res_min) = layer.forward_res(&x, ResidualKind::Minimal);
        let (y, res_full) = layer.forward_res(&x, ResidualKind::Full);
        let mx = residual_bytes(&res_min);
        let full = residual_bytes(&res_full);
        let sub = layer.submersivity();
        let (submersive, fast_vijp) = match &sub {
            Submersivity::Submersive { fast_path } => (true, *fast_path),
            Submersivity::NonSubmersive { .. } => (false, false),
        };
        costs.push(LayerCost {
            name: layer.name(),
            mx,
            m_theta: full.saturating_sub(mx),
            act_bytes: y.bytes(),
            in_bytes: x.bytes(),
            d_params: layer.n_params(),
            flops: layer.flops_estimate(x.shape()),
            submersive,
            fragmental_ok: matches!(
                sub,
                Submersivity::NonSubmersive {
                    fragmental_ok: true,
                    ..
                }
            ),
            fast_vijp,
        });
        x = y;
    }
    Ok(costs)
}

/// The methods of Table 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    Backprop,
    BackpropCkpt { segments: usize },
    Forward,
    ProjForward,
    RevBackprop,
    Moonwalk,
    PureMoonwalk,
    MoonwalkCkpt { segments: usize },
    MoonwalkFrag { block: usize, k: usize },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Backprop => "backprop".into(),
            Method::BackpropCkpt { segments } => format!("backprop_ckpt(c={segments})"),
            Method::Forward => "forward".into(),
            Method::ProjForward => "projforward".into(),
            Method::RevBackprop => "revbackprop".into(),
            Method::Moonwalk => "moonwalk".into(),
            Method::PureMoonwalk => "pure_moonwalk".into(),
            Method::MoonwalkCkpt { segments } => format!("moonwalk_ckpt(c={segments})"),
            Method::MoonwalkFrag { block, .. } => format!("moonwalk_frag(B={block})"),
        }
    }

    /// Engine-registry name for `autodiff::engine_by_name`.
    pub fn engine_name(&self) -> &'static str {
        match self {
            Method::Backprop => "backprop",
            Method::BackpropCkpt { .. } => "backprop_ckpt",
            Method::Forward => "forward",
            Method::ProjForward => "projforward",
            Method::RevBackprop => "revbackprop",
            Method::Moonwalk => "moonwalk",
            Method::PureMoonwalk => "pure_moonwalk",
            Method::MoonwalkCkpt { .. } => "moonwalk_ckpt",
            Method::MoonwalkFrag { .. } => "moonwalk_frag",
        }
    }
}

fn seg_bounds(depth: usize, segments: usize) -> Vec<(usize, usize)> {
    let seg_len = (depth + segments - 1) / segments;
    (0..segments)
        .map(|s| (s * seg_len, ((s + 1) * seg_len).min(depth)))
        .collect()
}

/// Predicted *extra* peak bytes for a method (Table 1, memory column).
pub fn predict_memory(method: &Method, costs: &[LayerCost]) -> usize {
    let depth = costs.len();
    let sum_mx: usize = costs.iter().map(|c| c.mx).sum();
    // Backprop's tape: every activation stored once + minimal residuals.
    let sum_full: usize = costs.iter().map(|c| c.mx + c.in_bytes).sum::<usize>()
        + costs.last().map(|c| c.act_bytes).unwrap_or(0);
    let max_act = costs.iter().map(|c| c.act_bytes.max(c.in_bytes)).max().unwrap_or(0);
    let max_mtheta = costs.iter().map(|c| c.m_theta).max().unwrap_or(0);
    // Cotangent-checkpoint bytes Moonwalk must keep across Phase II→III,
    // mirroring the engine's chain/anchor plan (§4.1 fallback with the
    // h₁-seed placement; fragments per §5.1 when enabled).
    let ckpt_cost = |frag_block: Option<(usize, usize)>| -> usize {
        let mut total = 0usize;
        let mut chain_ok = true;
        for c in costs {
            if c.submersive && chain_ok {
                // vijp continues the chain for free
            } else if chain_ok && c.fragmental_ok && frag_block.is_some() {
                let (block, k) = frag_block.unwrap();
                total += fragment_checkpoint_bytes(c.act_bytes, block, k);
            } else if c.d_params > 0 {
                // anchor: checkpoint this layer's output cotangent
                total += c.act_bytes;
                chain_ok = true;
                continue;
            } else {
                chain_ok = false;
            }
        }
        total
    };
    // Every method keeps at least one live activation while sweeping
    // (the forward/backward transient); charging it uniformly keeps the
    // model comparable across methods.
    match method {
        Method::Backprop => sum_full + max_act,
        Method::BackpropCkpt { segments } => {
            let bounds = seg_bounds(depth, (*segments).max(1));
            let boundary: usize = bounds
                .iter()
                .map(|&(lo, _)| costs[lo].in_bytes)
                .sum();
            let worst_seg = bounds
                .iter()
                .map(|&(lo, hi)| costs[lo..hi].iter().map(|c| c.mx + c.in_bytes).sum::<usize>())
                .max()
                .unwrap_or(0);
            boundary + worst_seg + max_act
        }
        // Activation + tangent (+ the next pair during a layer hop).
        Method::Forward => 3 * max_act,
        Method::ProjForward => 3 * max_act + costs.iter().map(|c| c.d_params * 4).sum::<usize>(),
        // x_out, reconstructed x_in, cotangent.
        Method::RevBackprop => 3 * max_act + costs.iter().map(|c| c.mx).max().unwrap_or(0),
        // Phase I residuals + §4.1 checkpoints + Phase-III (x, h) pair.
        Method::Moonwalk => sum_mx + ckpt_cost(None) + 2 * max_act,
        Method::PureMoonwalk => 3 * max_act + max_mtheta,
        Method::MoonwalkCkpt { segments } => {
            let bounds = seg_bounds(depth, (*segments).max(1));
            let boundary: usize = bounds.iter().map(|&(lo, _)| costs[lo].in_bytes).sum();
            let worst_seg = bounds
                .iter()
                .map(|&(lo, hi)| costs[lo..hi].iter().map(|c| c.mx).sum::<usize>())
                .max()
                .unwrap_or(0);
            boundary + worst_seg + ckpt_cost(None) + 2 * max_act
        }
        Method::MoonwalkFrag { block, k } => {
            sum_mx + ckpt_cost(Some((*block, *k))) + 2 * max_act
        }
    }
}

/// Predicted time in forward-pass units (Table 1, time column).
pub fn predict_time_units(method: &Method, costs: &[LayerCost], input_elems: usize) -> f64 {
    let fwd: f64 = costs.iter().map(|c| c.flops).sum();
    let suffix_flops: Vec<f64> = {
        // suffix_flops[i] = flops from layer i to the end
        let mut v = vec![0.0; costs.len() + 1];
        for i in (0..costs.len()).rev() {
            v[i] = v[i + 1] + costs[i].flops;
        }
        v
    };
    match method {
        // fwd + input-vjp + param-vjp ≈ 3×
        Method::Backprop => 3.0 * fwd,
        Method::BackpropCkpt { .. } => 4.0 * fwd,
        // one pass per parameter element, from its layer to the loss
        Method::Forward => {
            fwd + costs
                .iter()
                .enumerate()
                .map(|(i, c)| c.d_params as f64 * (fwd + suffix_flops[i]))
                .sum::<f64>()
        }
        Method::ProjForward => 2.0 * fwd,
        Method::RevBackprop => 4.0 * fwd,
        // Phase I+II ≈ 2×, Phase III ≈ 3× (fwd + vijp + param-vjp)
        Method::Moonwalk => 5.0 * fwd,
        Method::MoonwalkCkpt { .. } => 6.0 * fwd,
        Method::MoonwalkFrag { .. } => 5.0 * fwd,
        // one jvp pass per input element, then Phase III
        Method::PureMoonwalk => input_elems as f64 * fwd + 3.0 * fwd,
    }
}

/// Is a method applicable to this network at all?
pub fn applicable(method: &Method, costs: &[LayerCost]) -> bool {
    match method {
        Method::RevBackprop => costs.iter().all(|c| {
            // Our invertible configurations: act preserved size-wise and no
            // pooling/expansion. Approximation: in == out bytes everywhere.
            c.in_bytes == c.act_bytes
        }),
        Method::PureMoonwalk => {
            // Non-submersive layers must form a parameter-free prefix.
            let seed = costs
                .iter()
                .rposition(|c| !c.submersive)
                .map(|i| i + 1)
                .unwrap_or(0);
            costs[..seed].iter().all(|c| c.d_params == 0)
        }
        Method::MoonwalkFrag { .. } => costs
            .iter()
            .any(|c| c.fragmental_ok),
        _ => true,
    }
}

/// The planner: smallest-time applicable method under a byte budget.
/// `exact_only` excludes the high-variance ProjForward estimator.
pub fn plan(
    costs: &[LayerCost],
    budget_bytes: usize,
    exact_only: bool,
    input_elems: usize,
) -> Option<(Method, usize, f64)> {
    let depth = costs.len();
    let sqrt_l = (depth as f64).sqrt().round().max(1.0) as usize;
    let mut candidates = vec![
        Method::Backprop,
        Method::Moonwalk,
        Method::RevBackprop,
        Method::BackpropCkpt { segments: sqrt_l },
        Method::MoonwalkCkpt { segments: sqrt_l },
        Method::MoonwalkFrag { block: 8, k: 3 },
        Method::MoonwalkFrag { block: 16, k: 3 },
        Method::PureMoonwalk,
        Method::Forward,
    ];
    if !exact_only {
        candidates.insert(2, Method::ProjForward);
    }
    candidates
        .into_iter()
        .filter(|m| applicable(m, costs))
        .map(|m| {
            let mem = predict_memory(&m, costs);
            let t = predict_time_units(&m, costs, input_elems);
            (m, mem, t)
        })
        .filter(|&(_, mem, _)| mem <= budget_bytes)
        .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};
    use crate::util::Rng;

    fn costs_for(depth: usize) -> Vec<LayerCost> {
        let mut rng = Rng::new(0);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 32,
            depth,
            channels: 8,
            cin: 3,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        profile(&net, &[2, 32, 32, 3]).unwrap()
    }

    #[test]
    fn moonwalk_predicted_below_backprop() {
        let costs = costs_for(4);
        let bp = predict_memory(&Method::Backprop, &costs);
        let mw = predict_memory(&Method::Moonwalk, &costs);
        assert!(mw < bp, "moonwalk {mw} should be < backprop {bp}");
    }

    #[test]
    fn backprop_memory_scales_linearly_moonwalk_sublinearly() {
        let shallow = costs_for(2);
        let deep = costs_for(6);
        let bp_ratio = predict_memory(&Method::Backprop, &deep) as f64
            / predict_memory(&Method::Backprop, &shallow) as f64;
        let mw_ratio = predict_memory(&Method::Moonwalk, &deep) as f64
            / predict_memory(&Method::Moonwalk, &shallow) as f64;
        assert!(
            mw_ratio < bp_ratio,
            "moonwalk growth {mw_ratio} should be below backprop {bp_ratio}"
        );
    }

    #[test]
    fn planner_prefers_backprop_unbounded() {
        let costs = costs_for(4);
        let (m, _, _) = plan(&costs, usize::MAX, true, 32 * 32 * 3).unwrap();
        assert_eq!(m.engine_name(), "backprop");
    }

    #[test]
    fn planner_switches_to_moonwalk_under_budget() {
        let costs = costs_for(4);
        let bp = predict_memory(&Method::Backprop, &costs);
        let mw = predict_memory(&Method::Moonwalk, &costs);
        // A budget between the two forces the switch.
        let budget = (bp + mw) / 2;
        let (m, mem, _) = plan(&costs, budget, true, 32 * 32 * 3).unwrap();
        assert_ne!(m.engine_name(), "backprop");
        assert!(mem <= budget);
    }

    #[test]
    fn planner_none_when_impossible() {
        let costs = costs_for(2);
        assert!(plan(&costs, 16, true, 8).is_none());
    }

    #[test]
    fn forward_time_dominates() {
        let costs = costs_for(2);
        let n = 32 * 32 * 3;
        assert!(
            predict_time_units(&Method::Forward, &costs, n)
                > 10.0 * predict_time_units(&Method::Backprop, &costs, n)
        );
    }
}
