//! Table 1 (paper §11): verify the asymptotic time/memory claims of all
//! methods empirically — measure sweeps, fit log–log slopes, and print
//! the measured exponent next to the paper's.
//!
//! | method        | time     | memory          | expected slopes here |
//! | Backprop      | O(n²L)   | O(MxL + MθL)    | time~L¹; mem grows   |
//! | Backprop+ckpt | O(n²L)   | O(√(n(Mx+Mθ)L)) | time~L¹; mem ~L^0.5  |
//! | Forward       | O(n²dL²) | O(Mx+Mθ)        | time~L²; mem flat    |
//! | ProjForward   | O(n²L)   | O(Mx+Mθ)        | time~L¹; mem flat    |
//! | RevBackprop   | O(n²L)   | O(Mx+Mθ)        | time~L¹; mem flat    |
//! | Pure-Moonwalk | O(n³L)   | O(Mx+Mθ)        | time~n³; mem flat    |
//! | Moonwalk      | O(n²L)   | O(MxL + Mθ)     | time~L¹; mem ~flat   |

use moonwalk::autodiff::engine_by_name;
use moonwalk::coordinator::sweep::measure_engine;
use moonwalk::model::{build_invertible_cnn2d, build_mlp};
use moonwalk::nn::MeanLoss;
use moonwalk::tensor::Tensor;
use moonwalk::util::stats::loglog_slope;
use moonwalk::util::Rng;

fn fit(name: &str, xs: &[f64], times: &[f64], mems: &[f64], t_expect: &str, m_expect: &str) {
    let ts = loglog_slope(xs, times);
    let ms = loglog_slope(xs, mems);
    println!(
        "{name:<16} time slope {ts:>5.2} (paper: {t_expect:<8}) mem slope {ms:>5.2} (paper: {m_expect})"
    );
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");

    // ---------- scaling in depth L (resolution-preserving invertible
    // stack so per-layer cost is constant).
    println!("== scaling in depth L (constant-width stack) ==");
    let depths: Vec<usize> = if quick { vec![2, 4, 8] } else { vec![2, 4, 8, 16, 24] };
    let ls: Vec<f64> = depths.iter().map(|&d| d as f64).collect();
    for (name, t_exp, m_exp) in [
        ("backprop", "L^1", "O((Mx+Mθ)L)"),
        ("backprop_ckpt", "L^1", "O(sqrt(L))"),
        ("projforward", "L^1", "O(1)"),
        ("revbackprop", "L^1", "O(1)"),
        ("moonwalk", "L^1", "O(MxL+Mθ)"),
    ] {
        let mut times = Vec::new();
        let mut mems = Vec::new();
        for &depth in &depths {
            let mut rng = Rng::new(0);
            let net = build_invertible_cnn2d(8, depth, 0.1, &mut rng);
            let x = Tensor::randn(&[2, 16, 16, 8], 1.0, &mut rng);
            let engine = engine_by_name(name, 4, 0, 0)?;
            let (mem, time, _) = measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 1, 3)?;
            times.push(time);
            mems.push(mem as f64);
        }
        fit(name, &ls, &times, &mems, t_exp, m_exp);
    }

    // Forward-mode: L² in depth (micro MLP, few params per layer).
    {
        let depths: Vec<usize> = if quick { vec![1, 2, 3] } else { vec![1, 2, 3, 4, 5] };
        let ls: Vec<f64> = depths.iter().map(|&d| d as f64).collect();
        let mut times = Vec::new();
        let mut mems = Vec::new();
        for &depth in &depths {
            let mut rng = Rng::new(0);
            let dims = vec![6usize; depth + 1];
            let net = build_mlp(&dims, 0.1, &mut rng);
            let x = Tensor::randn(&[2, 6], 1.0, &mut rng);
            let engine = engine_by_name("forward", 4, 0, 0)?;
            let (mem, time, _) = measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 1, 3)?;
            times.push(time);
            mems.push(mem as f64);
        }
        fit("forward", &ls, &times, &mems, "L^2", "O(1)");
    }

    // ---------- scaling in width n: Pure-Moonwalk is n³ vs Backprop n².
    println!("\n== scaling in width n (fixed depth-2 MLP) ==");
    let widths: Vec<usize> = if quick { vec![8, 16, 32] } else { vec![8, 16, 32, 64, 96] };
    let ns: Vec<f64> = widths.iter().map(|&w| w as f64).collect();
    for (name, t_exp) in [("backprop", "n^2"), ("pure_moonwalk", "n^3")] {
        let mut times = Vec::new();
        let mut mems = Vec::new();
        for &w in &widths {
            let mut rng = Rng::new(0);
            let net = build_mlp(&[w, w, w], 0.1, &mut rng);
            let x = Tensor::randn(&[1, w], 1.0, &mut rng);
            let engine = engine_by_name(name, 4, 0, 0)?;
            let (mem, time, _) = measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 1, 3)?;
            times.push(time);
            mems.push(mem as f64);
        }
        fit(name, &ns, &times, &mems, t_exp, "-");
    }

    println!("\n(slopes are empirical; constants and cache effects blur small sweeps — \
              the ordering Backprop≈Moonwalk≪Forward and PureMoonwalk's extra power of n \
              are the Table-1 claims under test)");
    Ok(())
}
