//! Fig. 4 (paper §6.4): constrained (upper-triangular, Lemma 1) vs
//! unconstrained convolutions on classification — both should converge
//! to comparable accuracy (paper: both ≈90% on their task), showing the
//! submersive parameterization costs little expressivity.

use moonwalk::autodiff::engine_by_name;
use moonwalk::coordinator::{Optimizer, OptimizerKind, SyntheticSpec, TextureDataset, Trainer};
use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
use moonwalk::util::Rng;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 40 } else { 250 };
    println!("Fig 4 — constrained vs unconstrained convolutions ({steps} steps)");
    println!(
        "{:<14} {:>8} {:>11} {:>10} {:>10}",
        "model", "engine", "final_loss", "train_acc", "test_acc"
    );
    let mut accs = Vec::new();
    for constrained in [true, false] {
        let spec = SubmersiveCnn2dSpec {
            input_hw: 32,
            channels: 16,
            depth: 3,
            classes: 4,
            constrained,
            ..Default::default()
        };
        let mut rng = Rng::new(7);
        let mut net = build_cnn2d(&spec, &mut rng);
        let data = TextureDataset::generate(
            SyntheticSpec {
                classes: 4,
                hw: 32,
                cin: 3,
                noise: 1.25,
                seed: 7,
            },
            if quick { 96 } else { 640 },
        );
        let (train, test) = data.split(0.2);
        // Constrained trains with Moonwalk (exact, its whole point);
        // unconstrained with Backprop.
        let engine = engine_by_name(if constrained { "moonwalk" } else { "backprop" }, 4, 0, 0)?;
        let opt = Optimizer::new(OptimizerKind::Adam, 2e-3, &net, constrained);
        let mut trainer = Trainer::new(&mut net, engine.as_ref(), opt);
        let mut rng2 = Rng::new(8);
        let rep = trainer.train(&train, &test, 8, steps, &mut rng2, None)?;
        println!(
            "{:<14} {:>8} {:>11.4} {:>10.3} {:>10.3}",
            if constrained { "constrained" } else { "standard" },
            if constrained { "moonwalk" } else { "backprop" },
            rep.final_loss,
            rep.train_accuracy,
            rep.test_accuracy
        );
        accs.push(rep.test_accuracy);
    }
    println!(
        "\nheadline: constrained {:.3} vs unconstrained {:.3} test accuracy \
         (paper: both converge to ~0.90 — comparable expressivity)",
        accs[0], accs[1]
    );
    Ok(())
}
