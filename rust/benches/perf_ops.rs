//! Op-level microbench (§6 setup claim + §Perf): the convolutional vijp
//! operator should cost no more than the standard input-vjp — "our
//! implemented convolutional vijp operator does not introduce a
//! computational overhead".
//!
//! Also reports forward/vjp_w costs, the fast-path vs wavefront vijp
//! split, allocation churn (cold + steady-state), the data-parallel
//! replica-scaling family (`replicas_rows` in the JSON: step/reduce
//! medians at replicas {1,2[,4]} — the streamed all-reduce's overlap
//! signal), the transport-overhead family (`transport_rows`:
//! local vs unix-socket worker subprocesses at equal replica counts)
//! the conv-dispatch autotune family (`conv_rows`: ConvAlgo candidate
//! timings per shape, the cached winner, forced-Direct vs auto
//! medians, and first- vs second-pass calibration cost against a
//! persisted cache file), the budgeted-planner family (`planner_rows`:
//! the per-layer mixed-strategy plan vs the best whole-network engine
//! across a byte budget sweep — predicted and measured peaks plus the
//! budget invariant), the fault-injection recovery smoke
//! (`fault_rows`: killed / hung worker detect-respawn-replay cycle
//! time vs the clean step), the tracing-overhead family
//! (`trace_rows`: span capture off vs on step medians, events per
//! step, and the enabled-mode overhead ratio — the zero-cost-off
//! contract of `docs/OBSERVABILITY.md`) and the telemetry-endpoint
//! overhead family (`metrics_rows`: step medians with the HTTP
//! metrics listener off / on-unscraped / on-scraped-at-10Hz — the
//! < 2% live-scrape overhead contract; the off mode runs first
//! because listener threads are process-lived) for the §Perf log. The
//! `metrics` field carries an `obs::metrics::snapshot()` of the run's
//! counter/gauge registry. Families that need the
//! worker subprocess binary emit `skipped: true` rows when it is
//! absent instead of dropping the rows. The full field-by-field schema
//! of the emitted `BENCH_perf_ops.json` lives in
//! `docs/BENCH_SCHEMA.md`.
//!
//! Flags (after `--`):
//! * `--quick`      — 3 iterations instead of 15 (the tier-1 smoke run)
//! * `--threads N`  — worker-pool size (default: env / autodetect)
//! * `--gemm A`     — force a GEMM algorithm (auto|scalar|blocked|parallel)
//! * `--conv-algo A` — force a conv lowering (auto|direct|im2col|winograd);
//!   the `conv_rows` family temporarily overrides this while it times
//!   forced-direct vs auto, then restores the prior setting
//! * `--json PATH`  — machine-readable output (default BENCH_perf_ops.json)
//!
//! Compare `--threads 1` vs `--threads 4` on the 64×64×32 shapes for the
//! parallel-runtime speedup tracked in EXPERIMENTS.md §Perf.

use moonwalk::autodiff::engine_by_name;
use moonwalk::cli::Args;
use moonwalk::distributed::{split_batch, ReduceOp, ReplicaGroup, Shard};
use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
use moonwalk::nn::{Conv1d, Conv2d, Layer, MeanLoss, ResidualKind};
use moonwalk::runtime::pool;
use moonwalk::tensor::{arena, tracker, Tensor};
use moonwalk::util::json::Json;
use moonwalk::util::timer::bench;
use moonwalk::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    moonwalk::cli::configure_runtime(&args)?;
    let quick = args.has("quick");
    let iters = if quick { 3 } else { 15 };
    let threads = pool::threads();
    println!("threads={threads} quick={quick}");
    println!(
        "{:<34} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "config", "fwd_ms", "vjp_in_ms", "vjp_w_ms", "vijp_ms", "vijp/vjp"
    );
    let shapes: &[(usize, usize, usize, usize, usize, usize)] = &[
        // (batch, hw, ch, k, s, p)
        (4, 32, 16, 3, 2, 1),
        (4, 64, 32, 3, 2, 1),
        (2, 96, 32, 3, 2, 1),
        (2, 64, 32, 5, 3, 2), // s+p>=k: still fast path
        (2, 63, 16, 5, 3, 1), // s+p<k: wavefront (spatially coupled)
    ];
    let mut rows: Vec<Json> = Vec::new();
    for &(n, hw, ch, k, s, p) in shapes {
        let mut rng = Rng::new(1);
        let conv = Conv2d::new_submersive(k, ch, ch, s, p, false, &mut rng);
        let x = Tensor::randn(&[n, hw, hw, ch], 1.0, &mut rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &g);

        let fwd = bench(1, iters, || {
            std::hint::black_box(conv.forward(&x));
        });
        let vjp_in = bench(1, iters, || {
            std::hint::black_box(conv.vjp_input(&res, &g));
        });
        let vjp_w = bench(1, iters, || {
            std::hint::black_box(conv.vjp_params(&x, &g));
        });
        let vijp = bench(1, iters, || {
            std::hint::black_box(conv.vijp(&res, &h).unwrap());
        });
        let config = format!(
            "{n}x{hw}x{hw}x{ch} k{k}s{s}p{p}{}",
            if s + p >= k { "" } else { " (wave)" }
        );
        println!(
            "{:<34} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>10.2}",
            config,
            fwd.median_ms(),
            vjp_in.median_ms(),
            vjp_w.median_ms(),
            vijp.median_ms(),
            vijp.median / vjp_in.median
        );
        rows.push(Json::from_pairs(vec![
            ("config", config.as_str().into()),
            ("n", n.into()),
            ("hw", hw.into()),
            ("ch", ch.into()),
            ("k", k.into()),
            ("s", s.into()),
            ("p", p.into()),
            ("fwd_ms", fwd.median_ms().into()),
            ("vjp_in_ms", vjp_in.median_ms().into()),
            ("vjp_w_ms", vjp_w.median_ms().into()),
            ("vijp_ms", vijp.median_ms().into()),
            ("vijp_vjp_ratio", (vijp.median / vjp_in.median).into()),
        ]));
    }

    // Small-kernel family (ISSUE 2): per-op costs *below* ~100 µs — the
    // regime where PR 1's spawn-per-region scoped pool ate the parallel
    // win and the persistent team is supposed to keep it. Compare
    // `--threads 1` vs `--threads 4` medians: with cheap region dispatch
    // the 4-thread column should be ≤ the 1-thread column even here
    // (at worst neutral). The batch-1 rows exercise the spatial
    // (row-band) conv paths.
    println!("\nsmall kernels (medians in µs, threads={threads}):");
    println!(
        "{:<26} {:>10} {:>10} {:>10} {:>10}",
        "config", "fwd_us", "vjp_in_us", "vjp_w_us", "vijp_us"
    );
    let small_iters = iters * 40;
    let small_shapes: &[(usize, usize, usize, usize, usize, usize)] = &[
        // (batch, hw, ch, k, s, p)
        (4, 16, 8, 3, 2, 1),
        (8, 16, 8, 3, 2, 1),
        (1, 32, 8, 3, 2, 1),  // batch-1: spatial row-band paths
        (1, 48, 12, 3, 2, 1), // batch-1, a bit larger
    ];
    let mut small_rows: Vec<Json> = Vec::new();
    for &(n, hw, ch, k, s, p) in small_shapes {
        let mut rng = Rng::new(2);
        let conv = Conv2d::new_submersive(k, ch, ch, s, p, false, &mut rng);
        let x = Tensor::randn(&[n, hw, hw, ch], 1.0, &mut rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &g);
        let fwd = bench(5, small_iters, || {
            std::hint::black_box(conv.forward(&x));
        });
        let vjp_in = bench(5, small_iters, || {
            std::hint::black_box(conv.vjp_input(&res, &g));
        });
        let vjp_w = bench(5, small_iters, || {
            std::hint::black_box(conv.vjp_params(&x, &g));
        });
        let vijp = bench(5, small_iters, || {
            std::hint::black_box(conv.vijp(&res, &h).unwrap());
        });
        let config = format!("{n}x{hw}x{hw}x{ch} k{k}s{s}p{p}");
        println!(
            "{:<26} {:>10.1} {:>10.1} {:>10.1} {:>10.1}",
            config,
            fwd.median * 1e6,
            vjp_in.median * 1e6,
            vjp_w.median * 1e6,
            vijp.median * 1e6,
        );
        small_rows.push(Json::from_pairs(vec![
            ("config", config.as_str().into()),
            ("n", n.into()),
            ("hw", hw.into()),
            ("ch", ch.into()),
            ("fwd_us", (fwd.median * 1e6).into()),
            ("vjp_in_us", (vjp_in.median * 1e6).into()),
            ("vjp_w_us", (vjp_w.median * 1e6).into()),
            ("vijp_us", (vijp.median * 1e6).into()),
        ]));
    }
    // Batch-1 fragment reconstruction (Alg. 3), the Moonwalk
    // forward-reconstruction kernel the persistent pool de-serializes:
    // (image, block) tasks fan out even at N = 1.
    {
        let mut rng = Rng::new(3);
        let conv = Conv1d::new_fragmental(3, 16, 16, &mut rng);
        let x = Tensor::randn(&[1, 256, 16], 1.0, &mut rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let hp = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &hp);
        let frag = conv.fragment_capture(&hp, 16).unwrap();
        let rec = bench(5, small_iters, || {
            std::hint::black_box(conv.fragment_reconstruct(&frag, &h).unwrap());
        });
        println!(
            "{:<26} {:>10.1} (fragment_reconstruct, B=16)",
            "1x256x16 conv1d k3",
            rec.median * 1e6
        );
        small_rows.push(Json::from_pairs(vec![
            ("config", "1x256x16 conv1d k3 frag_rec B16".into()),
            ("frag_rec_us", (rec.median * 1e6).into()),
        ]));
    }
    // Raw region-dispatch overhead: an (almost) empty region with one
    // record per worker — the park/wake round trip the persistent team
    // optimizes vs the scoped pool's spawn+join.
    let dispatch_us = {
        let t = pool::threads().max(2);
        let mut sink = vec![0f32; t];
        let d = bench(20, small_iters * 5, || {
            pool::run_records(&mut sink, 1, t, |recs, chunk| {
                for (local, rec) in recs.enumerate() {
                    chunk[local] = rec as f32;
                }
            });
        });
        println!(
            "region dispatch ({} shares): {:.2} µs median",
            t,
            d.median * 1e6
        );
        d.median * 1e6
    };

    // Conv algorithm dispatch + autotune family (ISSUE 7): per-shape
    // candidate timings for the ConvAlgo lattice (direct / im2col /
    // winograd), the recorded winner, and the forced-Direct vs
    // auto-resolved forward medians. A fresh temp cache file makes the
    // first `autotune_with` a real calibration (`calib1_ms`); the table
    // is then dropped and reloaded from disk so the second pass
    // (`calib2_ms`, `cache_hit` all-cached) measures exactly what a
    // respawned worker pays: ~0, pure lookups. `winner_not_slower` is
    // computed from the calibration's own candidate medians (the winner
    // is the argmin, so it holds by construction — robust to re-measure
    // jitter), while `direct_fwd_ms`/`auto_fwd_ms` report the live
    // re-measured medians for the §Perf log.
    println!("\nconv algorithm autotune (fresh temp cache):");
    println!(
        "{:<26} {:<12} {:<9} {:>10} {:>10} {:>11} {:>11}",
        "config", "op", "winner", "direct_ms", "auto_ms", "calib1_ms", "calib2_ms"
    );
    let mut conv_rows: Vec<Json> = Vec::new();
    {
        use moonwalk::tensor::conv_algo;
        use std::time::Instant;
        let cache_path = std::env::temp_dir().join(format!(
            "moonwalk_conv_cache_bench_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&cache_path);
        conv_algo::set_cache_path(cache_path.to_str().expect("utf-8 temp path"));
        conv_algo::reload();
        let prev_override = conv_algo::conv_override().map(|a| a.label()).unwrap_or("auto");
        let tune_iters = iters.min(5);
        // (batch, hw/len, ch, k, s, p, two_d): the stride-1 3x3 2-D rows
        // are Winograd-eligible; the strided row and the 1-D row only
        // arbitrate Direct vs im2col. Geometries are distinct from every
        // other family so the cache cannot cross-talk.
        let conv_shapes: &[(usize, usize, usize, usize, usize, usize, bool)] = &[
            (2, 24, 8, 3, 1, 1, true),
            (2, 40, 16, 3, 1, 1, true),
            (2, 40, 16, 3, 2, 1, true),
            (4, 96, 16, 3, 1, 1, false),
        ];
        for &(n, hw, ch, k, s, p, two_d) in conv_shapes {
            let mut rng = Rng::new(7);
            enum AnyConv {
                C2(Conv2d),
                C1(Conv1d),
            }
            let (conv, x, config) = if two_d {
                (
                    AnyConv::C2(Conv2d::new(k, ch, ch, s, p, false, &mut rng)),
                    Tensor::randn(&[n, hw, hw, ch], 1.0, &mut rng),
                    format!("{n}x{hw}x{hw}x{ch} k{k}s{s}p{p} 2d"),
                )
            } else {
                (
                    AnyConv::C1(Conv1d::new(k, ch, ch, s, p, false, &mut rng)),
                    Tensor::randn(&[n, hw, ch], 1.0, &mut rng),
                    format!("{n}x{hw}x{ch} k{k}s{s}p{p} 1d"),
                )
            };
            let tune = |w: usize, it: usize| match &conv {
                AnyConv::C2(c) => c.autotune_with(&x, w, it),
                AnyConv::C1(c) => c.autotune_with(&x, w, it),
            };
            let fwd_once = || match &conv {
                AnyConv::C2(c) => std::hint::black_box(c.forward(&x)),
                AnyConv::C1(c) => std::hint::black_box(c.forward(&x)),
            };
            let t0 = Instant::now();
            let first = tune(1, tune_iters);
            let calib1_ms = t0.elapsed().as_secs_f64() * 1e3;
            // Drop the in-memory table: the second pass must be served
            // by the *persisted* file, like a respawned worker.
            conv_algo::reload();
            let t1 = Instant::now();
            let second = tune(1, tune_iters);
            let calib2_ms = t1.elapsed().as_secs_f64() * 1e3;
            let cache_hit = second.iter().all(|o| o.cached);
            conv_algo::set_conv_override("direct")?;
            let direct = bench(1, tune_iters, || {
                fwd_once();
            });
            conv_algo::set_conv_override("auto")?;
            let auto_run = bench(1, tune_iters, || {
                fwd_once();
            });
            for o in &first {
                let op = o.key.split(' ').next().unwrap_or("?");
                let is_fwd = op.ends_with("_fwd");
                let direct_cand_ms = o
                    .candidates
                    .iter()
                    .find(|(a, _)| *a == conv_algo::ConvAlgo::Direct)
                    .map(|&(_, ms)| ms);
                let winner_not_slower =
                    direct_cand_ms.map(|d| o.best_ms <= d).unwrap_or(true);
                println!(
                    "{:<26} {:<12} {:<9} {:>10.3} {:>10.3} {:>11.3} {:>11.3}",
                    config,
                    op,
                    o.algo.label(),
                    if is_fwd { direct.median_ms() } else { f64::NAN },
                    if is_fwd { auto_run.median_ms() } else { f64::NAN },
                    calib1_ms,
                    calib2_ms
                );
                let cands: Vec<Json> = o
                    .candidates
                    .iter()
                    .map(|&(a, ms)| {
                        Json::from_pairs(vec![("algo", a.label().into()), ("ms", ms.into())])
                    })
                    .collect();
                let mut pairs = vec![
                    ("config", config.as_str().into()),
                    ("op", op.into()),
                    ("skipped", false.into()),
                    ("winner", o.algo.label().into()),
                    ("winner_ms", o.best_ms.into()),
                    ("winner_not_slower", winner_not_slower.into()),
                    ("candidates", Json::Arr(cands)),
                    ("calib1_ms", calib1_ms.into()),
                    ("calib2_ms", calib2_ms.into()),
                    ("cache_hit_second", cache_hit.into()),
                ];
                if is_fwd {
                    pairs.push(("direct_fwd_ms", direct.median_ms().into()));
                    pairs.push(("auto_fwd_ms", auto_run.median_ms().into()));
                }
                conv_rows.push(Json::from_pairs(pairs));
            }
        }
        conv_algo::set_conv_override(prev_override)?;
        let _ = std::fs::remove_file(&cache_path);
    }

    // Ablation 1 (DESIGN.md §10): anchor placement. The h₁ seed
    // checkpoints the cotangent *after* the stride-2 entry conv (s²
    // smaller) vs naively at the upsample output.
    {
        use moonwalk::autodiff::{Moonwalk, MoonwalkOpts};
        use moonwalk::coordinator::sweep::measure_engine as me;
        let spec = SubmersiveCnn2dSpec {
            input_hw: 64,
            channels: 32,
            depth: 4,
            ..Default::default()
        };
        let mut rng = Rng::new(0);
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[4, 64, 64, 3], 1.0, &mut rng);
        println!("\nablation — cotangent anchor placement (moonwalk, depth 4):");
        for (label, naive) in [
            ("h1 seed (paper §4.3 variant)", false),
            ("naive (break-layer output)", true),
        ] {
            let engine = Moonwalk::new(MoonwalkOpts {
                naive_anchor: naive,
                ..Default::default()
            });
            let (mem, time, _) = me(&engine, &net, &x, &MeanLoss, 1, iters.min(5))?;
            println!(
                "  {label:<30} peak={} median={:.2}ms",
                tracker::fmt_bytes(mem),
                time * 1e3
            );
        }
    }

    // Allocation churn on the end-to-end engines (the §Perf metric):
    // `cold` is the first gradient computation (arena misses included),
    // `steady` a later one (arena warm — scratch churn should be ~0, only
    // the per-layer activation/cotangent/grad tensors remain).
    println!("\nallocation churn (one gradient computation, cold vs steady):");
    let spec = SubmersiveCnn2dSpec {
        input_hw: 64,
        channels: 32,
        depth: 4,
        ..Default::default()
    };
    let mut rng = Rng::new(0);
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[4, 64, 64, 3], 1.0, &mut rng);
    let mut churn: Vec<Json> = Vec::new();
    for name in ["backprop", "moonwalk"] {
        let engine = engine_by_name(name, 4, 0, 0)?;
        let run = |engine: &dyn moonwalk::autodiff::GradEngine| {
            tracker::measure(|| {
                engine
                    .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
                    .unwrap()
            })
        };
        // Drain the process-global arena so `cold` really is a cold
        // start for every engine, not just the first one measured.
        moonwalk::tensor::arena::clear();
        let (_, cold) = run(engine.as_ref());
        let (_, steady) = run(engine.as_ref());
        println!(
            "  {name:<10} cold_allocs={:<6} steady_allocs={:<6} peak={}",
            cold.allocs,
            steady.allocs,
            tracker::fmt_bytes(steady.peak_extra_bytes)
        );
        churn.push(Json::from_pairs(vec![
            ("engine", name.into()),
            ("cold_allocs", cold.allocs.into()),
            ("steady_allocs", steady.allocs.into()),
            ("peak_extra_bytes", steady.peak_extra_bytes.into()),
        ]));
    }

    // Replica-scaling family (ISSUE 3): one Moonwalk engine per replica
    // over equal shards of a global batch, per-layer gradients
    // all-reduced streamed. The overlap signal: `reduce_ms` is folded on
    // the last-delivering replica's thread *inside* the step, so it must
    // not show up additively in `step_ms` — compare replicas=1 vs N step
    // medians against the reduce share. The tier-1 `--quick` smoke runs
    // replicas {1, 2}; full runs add 4.
    println!("\nreplica scaling (moonwalk, global batch 8):");
    println!(
        "{:<12} {:>12} {:>12} {:>14} {:>12}",
        "replicas", "step_ms", "reduce_ms", "reduce/step", "steps/s"
    );
    let mut replica_rows: Vec<Json> = Vec::new();
    {
        let spec = SubmersiveCnn2dSpec {
            input_hw: 32,
            channels: 16,
            depth: 3,
            ..Default::default()
        };
        let mut rng = Rng::new(4);
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[8, 32, 32, 3], 1.0, &mut rng);
        let engine = engine_by_name("moonwalk", 4, 0, 0)?;
        let replica_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
        for &r in replica_counts {
            let xs = split_batch(&x, r)?;
            let shards: Vec<Shard<'_>> = xs
                .iter()
                .map(|x| Shard {
                    x,
                    loss: &MeanLoss,
                })
                .collect();
            let group = ReplicaGroup::new(r)?;
            // One probed step for the reduce-time share, then medians.
            let probe = group.compute(&net, engine.as_ref(), &shards, ReduceOp::Mean)?;
            let st = bench(1, iters.min(8), || {
                std::hint::black_box(
                    group
                        .compute(&net, engine.as_ref(), &shards, ReduceOp::Mean)
                        .unwrap(),
                );
            });
            let overlap = probe.reduce_s / st.median.max(1e-12);
            println!(
                "{:<12} {:>12.3} {:>12.3} {:>14.3} {:>12.2}",
                r,
                st.median_ms(),
                probe.reduce_s * 1e3,
                overlap,
                1.0 / st.median.max(1e-12)
            );
            replica_rows.push(Json::from_pairs(vec![
                ("replicas", r.into()),
                ("step_ms", st.median_ms().into()),
                ("reduce_ms", (probe.reduce_s * 1e3).into()),
                ("reduce_step_ratio", overlap.into()),
                ("throughput_steps_per_s", (1.0 / st.median.max(1e-12)).into()),
                ("loss", (probe.loss as f64).into()),
            ]));
        }
    }

    // Transport-overhead family (ISSUE 4): the same replicated step
    // through the in-process transport vs one worker subprocess per
    // replica over unix sockets. `broadcast_ms` is the per-step
    // parameter upload the remote transport adds; `step_ms` includes
    // shard upload + compute + streamed gradient download. Compare the
    // local and unix rows at equal replica counts for the
    // process-boundary cost (the gradients themselves are bit-identical
    // across the two transports — tests/transport.rs).
    println!("\ntransport overhead (moonwalk, global batch 8):");
    println!(
        "{:<10} {:>9} {:>14} {:>12} {:>12} {:>12}",
        "transport", "replicas", "broadcast_ms", "step_ms", "reduce_ms", "steps/s"
    );
    let mut transport_rows: Vec<Json> = Vec::new();
    {
        use moonwalk::distributed::transport::{
            EngineSpec, LocalTransport, LossSpec, ShardSpec, Transport, UnixTransport,
            UnixTransportOpts,
        };
        use moonwalk::model::config::Config;
        let cfg = Config::from_json(
            &Json::parse(
                r#"{"arch": "cnn2d", "depth": 3, "channels": 16, "input_hw": 32,
                    "cin": 3, "classes": 8, "seed": 4}"#,
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        )?;
        let mut rng = Rng::new(cfg.seed);
        let net = cfg.build_network(&mut rng);
        let x = Tensor::randn(&[8, 32, 32, 3], 1.0, &mut rng);
        let engine = engine_by_name("moonwalk", cfg.block, cfg.checkpoint_every, cfg.seed)?;
        // The worker subprocess is the real binary; absent (e.g. a
        // lib-only build) the unix rows are skipped, not failed.
        let worker_bin: Option<&str> = option_env!("CARGO_BIN_EXE_moonwalk");
        let replica_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
        for transport_name in ["local", "unix"] {
            for &r in replica_counts {
                let mut transport: Box<dyn Transport> = match transport_name {
                    "local" => Box::new(LocalTransport::new(r)),
                    _ => {
                        // Skips still emit a row (with a `skipped`
                        // marker) so the JSON family's shape does not
                        // depend on the build having a worker binary.
                        let Some(bin) = worker_bin else {
                            println!("unix       {r:>9} (skipped: no worker binary)");
                            transport_rows.push(Json::from_pairs(vec![
                                ("transport", "unix".into()),
                                ("replicas", r.into()),
                                ("skipped", true.into()),
                                ("reason", "no worker binary".into()),
                            ]));
                            continue;
                        };
                        let mut opts = UnixTransportOpts::new(
                            r,
                            cfg.to_json().to_string(),
                            EngineSpec::new("moonwalk"),
                        );
                        opts.worker_bin = Some(std::path::PathBuf::from(bin));
                        match UnixTransport::spawn(opts) {
                            Ok(t) => Box::new(t),
                            Err(e) => {
                                println!("unix       {r:>9} (skipped: {e})");
                                let reason = format!("spawn failed: {e}");
                                transport_rows.push(Json::from_pairs(vec![
                                    ("transport", "unix".into()),
                                    ("replicas", r.into()),
                                    ("skipped", true.into()),
                                    ("reason", reason.as_str().into()),
                                ]));
                                continue;
                            }
                        }
                    }
                };
                let xs = split_batch(&x, r)?;
                let bcast = bench(1, iters.min(8), || {
                    transport.broadcast(&net).unwrap();
                });
                let shards: Vec<ShardSpec<'_>> = xs
                    .iter()
                    .map(|x| ShardSpec {
                        x,
                        loss: LossSpec::Mean,
                    })
                    .collect();
                let run_step = |t: &mut dyn Transport| {
                    t.step(&net, engine.as_ref(), &shards, ReduceOp::Mean, &|_, g| {
                        drop(g)
                    })
                    .unwrap()
                };
                let probe = run_step(transport.as_mut());
                let st = bench(1, iters.min(8), || {
                    std::hint::black_box(run_step(transport.as_mut()));
                });
                println!(
                    "{:<10} {:>9} {:>14.3} {:>12.3} {:>12.3} {:>12.2}",
                    transport_name,
                    r,
                    bcast.median_ms(),
                    st.median_ms(),
                    probe.reduce_s * 1e3,
                    1.0 / st.median.max(1e-12)
                );
                transport_rows.push(Json::from_pairs(vec![
                    ("transport", transport_name.into()),
                    ("replicas", r.into()),
                    ("skipped", false.into()),
                    ("broadcast_ms", bcast.median_ms().into()),
                    ("step_ms", st.median_ms().into()),
                    ("reduce_ms", (probe.reduce_s * 1e3).into()),
                    ("throughput_steps_per_s", (1.0 / st.median.max(1e-12)).into()),
                    ("loss", (probe.loss as f64).into()),
                ]));
            }
        }
    }

    // Budgeted-planner family (ISSUE 5): sweep byte budgets on the
    // fragmental 1-D net — the architecture where per-layer strategy
    // mixing (fragment-block search + selective checkpoints) separates
    // from whole-network engine selection — and compare the compiled
    // per-layer plan against `memsim::plan`'s best single engine at the
    // same budget, predicted *and* measured. `beats_single` marks budget
    // points where the mixed plan wins on predicted peak bytes at
    // equal-or-better predicted time (the memory/depth frontier claim);
    // `planned_measured_peak` vs the budget is the budget invariant,
    // live.
    println!("\nbudgeted per-layer planner (fragmental 1-D, batch 2):");
    println!(
        "{:<12} {:<26} {:>12} {:>10} {:>12} {:>12} {:>10} {:>6}",
        "budget", "mix", "planned_pk", "t_units", "measured_pk", "single_pk", "single_t", "beats"
    );
    let mut planner_rows: Vec<Json> = Vec::new();
    {
        use moonwalk::autodiff::PlannedEngine;
        use moonwalk::model::{build_cnn1d_fragmental, FragmentalCnn1dSpec};
        use moonwalk::plan;
        // Depth 8: deep enough that BackpropCkpt's √L memory does not
        // fit at the tight end of the sweep, so the mixed plan's
        // fragment-block search has a 5×fwd single-engine baseline to
        // beat there (see `mixed_plan_beats_single_engine_at_some_budget`).
        let spec = FragmentalCnn1dSpec {
            input_len: 128,
            channels: 8,
            depth: 8,
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        let net = build_cnn1d_fragmental(&spec, &mut rng);
        let in_shape = [2usize, 128, 3];
        let x = Tensor::randn(&in_shape, 1.0, &mut rng);
        let probes = plan::probe_network(&net, &in_shape, plan::DEFAULT_FRAG_BLOCKS)?;
        let costs: Vec<moonwalk::memsim::LayerCost> =
            probes.iter().map(|p| p.cost.clone()).collect();
        let input_elems: usize = in_shape.iter().product();
        let fwd_flops: f64 = costs.iter().map(|c| c.flops).sum();
        let frontier = plan::build_frontier(&probes);
        let lo = frontier.min_peak();
        let hi = moonwalk::memsim::predict_memory(&moonwalk::memsim::Method::Backprop, &costs)
            .max(frontier.max_useful_peak())
            .max(lo + 1);
        let fracs: &[usize] = if quick { &[0, 4, 8] } else { &[0, 2, 4, 6, 8] };
        for &f in fracs {
            let budget = lo + (hi - lo) * f / 8;
            let compiled = match frontier.select(&probes, Some(budget)) {
                Ok(p) => p,
                Err(_) => continue,
            };
            let single = moonwalk::memsim::plan(&costs, budget, true, input_elems);
            // Measured: one tracked + timed gradient computation of the
            // planned engine under this budget (grad-free accounting,
            // dropping sink — the paper's memory metric).
            let engine = PlannedEngine::with_budget(Some(budget));
            engine.prepare(&net, &in_shape)?;
            let (measured_peak, step_s, _loss) = moonwalk::coordinator::sweep::measure_engine(
                &engine,
                &net,
                &x,
                &MeanLoss,
                1,
                iters.min(5),
            )?;
            let planned_t = compiled.time_units / fwd_flops.max(1.0);
            let (single_label, single_peak, single_t) = match &single {
                Some((m, mem, t)) => (m.label(), *mem, *t / fwd_flops.max(1.0)),
                None => ("none".to_string(), 0, 0.0),
            };
            // No single engine fitting is NOT a win by default — the
            // acceptance gate requires beating a real baseline.
            let beats = single
                .as_ref()
                .map(|&(_, mem, t)| {
                    compiled.planned_peak < mem && compiled.time_units <= t
                })
                .unwrap_or(false);
            println!(
                "{:<12} {:<26} {:>12} {:>10.2} {:>12} {:>12} {:>10.2} {:>6}",
                tracker::fmt_bytes(budget),
                compiled.mix(),
                tracker::fmt_bytes(compiled.planned_peak),
                planned_t,
                tracker::fmt_bytes(measured_peak),
                tracker::fmt_bytes(single_peak),
                single_t,
                beats
            );
            planner_rows.push(Json::from_pairs(vec![
                ("budget", budget.into()),
                ("mix", compiled.mix().as_str().into()),
                ("planned_peak", compiled.planned_peak.into()),
                ("conservative_peak", compiled.conservative_peak.into()),
                ("planned_time_fwd_units", planned_t.into()),
                ("planned_step_ms", (step_s * 1e3).into()),
                ("planned_measured_peak", measured_peak.into()),
                ("budget_respected", (measured_peak <= budget).into()),
                ("single_engine", single_label.as_str().into()),
                ("single_peak", single_peak.into()),
                ("single_time_fwd_units", single_t.into()),
                ("beats_single", beats.into()),
            ]));
        }
    }

    // Reversible-depth grid (ISSUE 9): steps/s and tracked peak bytes vs
    // depth for a coupling-block stack, backprop vs moonwalk vs the
    // planned engine at its tightest budget. The story in numbers: the
    // zero-residual blocks keep moonwalk/planned peaks flat in depth
    // while backprop's activation tape grows linearly
    // (`tests/reversible.rs` asserts the same shape; this family tracks
    // the constants).
    println!("\nreversible depth grid (coupling revnet, channels 8, batch 4):");
    println!(
        "{:<8} {:<10} {:>12} {:>12}",
        "depth", "engine", "steps/s", "peak_bytes"
    );
    let mut depth_rows: Vec<Json> = Vec::new();
    {
        use moonwalk::autodiff::{Backprop, Moonwalk, MoonwalkOpts, PlannedEngine};
        use moonwalk::model::{build_revnet, RevNetSpec, RevNetVariant};
        use moonwalk::plan;
        let depths: &[usize] = if quick { &[8, 128] } else { &[8, 32, 128] };
        for &depth in depths {
            let mut rng = Rng::new(9);
            let net = build_revnet(
                &RevNetSpec {
                    channels: 8,
                    depth,
                    variant: RevNetVariant::Coupling,
                    ..Default::default()
                },
                &mut rng,
            );
            let x = Tensor::randn(&[4, 8], 1.0, &mut rng);
            let probes = plan::probe_network(&net, x.shape(), plan::DEFAULT_FRAG_BLOCKS)?;
            let tight = plan::build_frontier(&probes).min_peak();
            let engines: Vec<(&str, Box<dyn moonwalk::autodiff::GradEngine>)> = vec![
                ("backprop", Box::new(Backprop)),
                ("moonwalk", Box::new(Moonwalk::new(MoonwalkOpts::default()))),
                ("planned", Box::new(PlannedEngine::with_budget(Some(tight)))),
            ];
            for (name, engine) in engines {
                let (peak, secs, _loss) = moonwalk::coordinator::sweep::measure_engine(
                    engine.as_ref(),
                    &net,
                    &x,
                    &MeanLoss,
                    1,
                    iters.min(5),
                )?;
                let steps_per_s = if secs > 0.0 { 1.0 / secs } else { 0.0 };
                println!(
                    "{:<8} {:<10} {:>12.1} {:>12}",
                    depth,
                    name,
                    steps_per_s,
                    tracker::fmt_bytes(peak)
                );
                depth_rows.push(Json::from_pairs(vec![
                    ("depth", depth.into()),
                    ("engine", name.into()),
                    ("variant", "coupling".into()),
                    ("channels", 8usize.into()),
                    ("batch", 4usize.into()),
                    ("steps_per_s", steps_per_s.into()),
                    ("peak_bytes", peak.into()),
                    ("tight_budget", tight.into()),
                ]));
            }
        }
    }

    // Fault-injection smoke (ISSUE 6): the supervised unix transport's
    // end-to-end recovery cycle — detect a killed / hung worker, respawn
    // it, re-upload parameters and replay the step — timed against the
    // clean step (`fault = none`). Runs in `--quick` too: this *is* the
    // tier-1 fault smoke. Skipped gracefully without a worker binary.
    println!("\nfault-injection recovery (unix, moonwalk, replicas 2):");
    println!(
        "{:<10} {:>14} {:>9} {:>10}",
        "fault", "recovery_ms", "retries", "failovers"
    );
    let mut fault_rows: Vec<Json> = Vec::new();
    {
        use moonwalk::distributed::transport::{
            Deadlines, EngineSpec, FaultPlan, LossSpec, ShardSpec, UnixTransport,
            UnixTransportOpts,
        };
        use moonwalk::distributed::RetryPolicy;
        use moonwalk::model::config::Config;
        use std::time::{Duration, Instant};
        let cfg = Config::from_json(
            &Json::parse(
                r#"{"arch": "cnn2d", "depth": 2, "channels": 8, "input_hw": 16,
                    "cin": 2, "classes": 4, "seed": 6}"#,
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        )?;
        let mut rng = Rng::new(cfg.seed);
        let net = cfg.build_network(&mut rng);
        let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
        let xs = split_batch(&x, 2)?;
        let engine = engine_by_name("moonwalk", cfg.block, cfg.checkpoint_every, cfg.seed)?;
        let fault_specs = ["none", "kill:1@0", "hang:1@0"];
        match option_env!("CARGO_BIN_EXE_moonwalk") {
            None => {
                // Same skip symmetry as the transport family: every
                // fault spec still gets a row, marked `skipped`, so the
                // JSON consumer sees the full grid either way.
                println!("(skipped: no worker binary)");
                for fault in fault_specs {
                    fault_rows.push(Json::from_pairs(vec![
                        ("fault", fault.into()),
                        ("skipped", true.into()),
                        ("reason", "no worker binary".into()),
                    ]));
                }
            }
            Some(bin) => {
                // Short heartbeat so the hung-worker row measures the
                // supervisor's grace floor, not the 120 s default.
                let deadlines = Deadlines {
                    accept: Duration::from_secs(30),
                    hello: Duration::from_secs(10),
                    step: Some(Duration::from_secs(60)),
                    heartbeat_ms: 50,
                };
                for fault in fault_specs {
                    let mut opts = UnixTransportOpts::new(
                        2,
                        cfg.to_json().to_string(),
                        EngineSpec::new("moonwalk"),
                    );
                    opts.worker_bin = Some(std::path::PathBuf::from(bin));
                    opts.deadlines = deadlines;
                    if fault != "none" {
                        opts.faults = FaultPlan::parse(fault)?;
                    }
                    let transport = match UnixTransport::spawn(opts) {
                        Ok(t) => t,
                        Err(e) => {
                            println!("{fault:<10} (skipped: {e})");
                            let reason = format!("spawn failed: {e}");
                            fault_rows.push(Json::from_pairs(vec![
                                ("fault", fault.into()),
                                ("skipped", true.into()),
                                ("reason", reason.as_str().into()),
                            ]));
                            continue;
                        }
                    };
                    let group = ReplicaGroup::with_transport(Box::new(transport))?;
                    group.sync(&net)?;
                    let shards: Vec<ShardSpec<'_>> = xs
                        .iter()
                        .map(|x| ShardSpec {
                            x,
                            loss: LossSpec::Mean,
                        })
                        .collect();
                    let policy = RetryPolicy {
                        retries: 2,
                        backoff_ms: 5,
                        failover: false,
                    };
                    let t0 = Instant::now();
                    let (res, stats) = group.step_retrying(
                        &net,
                        engine.as_ref(),
                        &shards,
                        ReduceOp::Mean,
                        policy,
                    )?;
                    let recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
                    println!(
                        "{:<10} {:>14.3} {:>9} {:>10}",
                        fault, recovery_ms, stats.retries, stats.failovers
                    );
                    fault_rows.push(Json::from_pairs(vec![
                        ("fault", fault.into()),
                        ("skipped", false.into()),
                        ("recovery_ms", recovery_ms.into()),
                        ("retries", stats.retries.into()),
                        ("failovers", stats.failovers.into()),
                        ("loss", (res.loss as f64).into()),
                    ]));
                }
            }
        }
    }

    // Tracing-overhead family (ISSUE 8): a small Moonwalk gradient step
    // with span capture disabled (the default) and enabled. The contract
    // (docs/OBSERVABILITY.md, ARCHITECTURE.md §2.6) is that the disabled
    // path is one relaxed atomic load per would-be span, so
    // `overhead_vs_off` on the enabled row bounds the *worst case* and
    // the disabled row's step median must sit within noise (< 2%) of
    // any untraced build. When the whole bench runs under `--trace` the
    // span rings belong to the export — draining them here would drop
    // the events from the merged trace — so the family emits `skipped`
    // rows instead.
    println!("\ntracing overhead (moonwalk, 2x16x16 ch8 depth 3):");
    println!(
        "{:<10} {:>12} {:>14} {:>16}",
        "spans", "step_ms", "events/step", "overhead_vs_off"
    );
    let mut trace_rows: Vec<Json> = Vec::new();
    if moonwalk::obs::export::trace_active() {
        println!("(skipped: --trace active; span buffers belong to the export)");
        for mode in [false, true] {
            trace_rows.push(Json::from_pairs(vec![
                ("enabled", mode.into()),
                ("skipped", true.into()),
                ("reason", "--trace active".into()),
            ]));
        }
    } else {
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            channels: 8,
            depth: 3,
            ..Default::default()
        };
        let mut rng = Rng::new(8);
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[2, 16, 16, 3], 1.0, &mut rng);
        let engine = engine_by_name("moonwalk", 4, 0, 0)?;
        let was = moonwalk::obs::span::enabled();
        let warmup = 2;
        let trace_iters = iters.min(10);
        let mut off_median = f64::NAN;
        for mode in [false, true] {
            moonwalk::obs::span::set_enabled(mode);
            // Start each mode from empty rings so the event count below
            // is attributable to exactly this mode's calls.
            let _ = moonwalk::obs::span::drain_all();
            let st = bench(warmup, trace_iters, || {
                engine
                    .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
                    .unwrap();
            });
            let events: usize = moonwalk::obs::span::drain_all()
                .iter()
                .map(|t| t.events.len())
                .sum();
            let events_per_step = events as f64 / (warmup + trace_iters) as f64;
            let overhead = if mode {
                (st.median - off_median) / off_median.max(1e-12)
            } else {
                off_median = st.median;
                0.0
            };
            println!(
                "{:<10} {:>12.3} {:>14.1} {:>15.2}%",
                if mode { "on" } else { "off" },
                st.median_ms(),
                events_per_step,
                overhead * 1e2
            );
            trace_rows.push(Json::from_pairs(vec![
                ("enabled", mode.into()),
                ("skipped", false.into()),
                ("step_ms", st.median_ms().into()),
                ("events_per_step", events_per_step.into()),
                ("overhead_vs_off", overhead.into()),
            ]));
        }
        moonwalk::obs::span::set_enabled(was);
    }

    // Telemetry-endpoint overhead family (ISSUE 10): the same small
    // Moonwalk gradient step with the HTTP metrics listener off, on but
    // never scraped, and on while a 10 Hz scraper hammers `/metrics`.
    // The contract (docs/OBSERVABILITY.md) is < 2% overhead in every
    // mode: the listener thread only reads the registry and the
    // pool/arena/tracker atomics, so the hot path never notices it.
    // The "off" mode must run first — listener threads are
    // process-lived by design, so once one exists there is no way back
    // to a listener-free process. When a listener is already active
    // (env `MOONWALK_METRICS_LISTEN` resolved by `configure_runtime`)
    // the off row emits `skipped` and the on rows reuse that listener.
    println!("\ntelemetry endpoint overhead (moonwalk, 2x16x16 ch8 depth 3):");
    println!(
        "{:<18} {:>12} {:>16} {:>10}",
        "mode", "step_ms", "overhead_vs_off", "scrapes"
    );
    let mut metrics_rows: Vec<Json> = Vec::new();
    {
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            channels: 8,
            depth: 3,
            ..Default::default()
        };
        let mut rng = Rng::new(10);
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[2, 16, 16, 3], 1.0, &mut rng);
        let engine = engine_by_name("moonwalk", 4, 0, 0)?;
        let warmup = 2;
        let m_iters = iters.min(10);
        let pre_bound = moonwalk::obs::http::bound_addr();
        let mut off_median = f64::NAN;
        if pre_bound.is_some() {
            println!("{:<18} (skipped: a listener is already active)", "off");
            metrics_rows.push(Json::from_pairs(vec![
                ("mode", "off".into()),
                ("skipped", true.into()),
                ("reason", "listener already active".into()),
            ]));
        } else {
            let st = bench(warmup, m_iters, || {
                engine
                    .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
                    .unwrap();
            });
            off_median = st.median;
            println!(
                "{:<18} {:>12.3} {:>15.2}% {:>10}",
                "off",
                st.median_ms(),
                0.0,
                "-"
            );
            metrics_rows.push(Json::from_pairs(vec![
                ("mode", "off".into()),
                ("skipped", false.into()),
                ("step_ms", st.median_ms().into()),
                ("overhead_vs_off", 0.0.into()),
            ]));
        }
        let addr = match pre_bound {
            Some(a) => a,
            None => moonwalk::obs::http::serve("127.0.0.1:0")?,
        };
        // On, never scraped: the listener thread is parked in accept().
        let st = bench(warmup, m_iters, || {
            engine
                .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
                .unwrap();
        });
        let overhead = (st.median - off_median) / off_median.max(1e-12);
        println!(
            "{:<18} {:>12.3} {:>15.2}% {:>10}",
            "on_unscraped",
            st.median_ms(),
            overhead * 1e2,
            "-"
        );
        let mut row = vec![
            ("mode", Json::from("on_unscraped")),
            ("skipped", false.into()),
            ("step_ms", st.median_ms().into()),
        ];
        if off_median.is_finite() {
            row.push(("overhead_vs_off", overhead.into()));
        }
        metrics_rows.push(Json::from_pairs(row));
        // On, scraped at 10 Hz from a background thread while the
        // step runs — the worst case a real Prometheus poller presents.
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let flag = stop.clone();
        let scraper = std::thread::spawn(move || {
            let mut n = 0u64;
            while !flag.load(std::sync::atomic::Ordering::Relaxed) {
                if moonwalk::obs::http::get(addr, "/metrics").is_ok() {
                    n += 1;
                }
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
            n
        });
        let st = bench(warmup, m_iters, || {
            engine
                .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
                .unwrap();
        });
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let scrapes = scraper.join().unwrap_or(0);
        let overhead = (st.median - off_median) / off_median.max(1e-12);
        println!(
            "{:<18} {:>12.3} {:>15.2}% {:>10}",
            "on_scraped_10hz",
            st.median_ms(),
            overhead * 1e2,
            scrapes
        );
        let mut row = vec![
            ("mode", Json::from("on_scraped_10hz")),
            ("skipped", false.into()),
            ("step_ms", st.median_ms().into()),
            ("scrapes", (scrapes as usize).into()),
        ];
        if off_median.is_finite() {
            row.push(("overhead_vs_off", overhead.into()));
        }
        metrics_rows.push(Json::from_pairs(row));
    }

    // Pool lifecycle + arena recycle-rate snapshot for the run (monotone
    // process counters — diff across runs at equal workloads).
    let pstats = pool::stats();
    println!(
        "\npool: regions={} wakes={} parks={} workers={} | arena: hits={} misses={}",
        pstats.regions,
        pstats.wakes,
        pstats.parks,
        pstats.workers_spawned,
        arena::hits(),
        arena::misses()
    );

    // Machine-readable output for the perf-trajectory tracking (CI keeps
    // one BENCH_perf_ops.json per run; diff across commits).
    let json_path = args.get_or("json", "BENCH_perf_ops.json");
    let out = Json::from_pairs(vec![
        ("bench", "perf_ops".into()),
        ("threads", threads.into()),
        ("quick", quick.into()),
        ("iters", iters.into()),
        ("rows", Json::Arr(rows)),
        ("small_rows", Json::Arr(small_rows)),
        ("conv_rows", Json::Arr(conv_rows)),
        ("replicas_rows", Json::Arr(replica_rows)),
        ("transport_rows", Json::Arr(transport_rows)),
        ("planner_rows", Json::Arr(planner_rows)),
        ("depth_rows", Json::Arr(depth_rows)),
        ("fault_rows", Json::Arr(fault_rows)),
        ("trace_rows", Json::Arr(trace_rows)),
        ("metrics_rows", Json::Arr(metrics_rows)),
        ("metrics", moonwalk::obs::metrics::snapshot()),
        ("dispatch_us", dispatch_us.into()),
        (
            "pool",
            Json::from_pairs(vec![
                ("regions", pstats.regions.into()),
                ("wakes", pstats.wakes.into()),
                ("parks", pstats.parks.into()),
                ("workers_spawned", pstats.workers_spawned.into()),
            ]),
        ),
        (
            "arena",
            Json::from_pairs(vec![
                ("hits", arena::hits().into()),
                ("misses", arena::misses().into()),
            ]),
        ),
        ("churn", Json::Arr(churn)),
    ]);
    std::fs::write(json_path, out.to_string())?;
    println!("\nwrote {json_path}");
    if let Some(path) = moonwalk::obs::export::finish()? {
        println!("trace written to {}", path.display());
    }
    Ok(())
}
