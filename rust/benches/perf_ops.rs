//! Op-level microbench (§6 setup claim + §Perf): the convolutional vijp
//! operator should cost no more than the standard input-vjp — "our
//! implemented convolutional vijp operator does not introduce a
//! computational overhead".
//!
//! Also reports forward/vjp_w costs and the fast-path vs wavefront vijp
//! split, plus allocation churn for the §Perf log.

use moonwalk::nn::{Conv2d, Layer, ResidualKind};
use moonwalk::tensor::{tracker, Tensor};
use moonwalk::util::timer::bench;
use moonwalk::util::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 3 } else { 15 };
    println!(
        "{:<34} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "config", "fwd_ms", "vjp_in_ms", "vjp_w_ms", "vijp_ms", "vijp/vjp"
    );
    let shapes: &[(usize, usize, usize, usize, usize, usize)] = &[
        // (batch, hw, ch, k, s, p)
        (4, 32, 16, 3, 2, 1),
        (4, 64, 32, 3, 2, 1),
        (2, 96, 32, 3, 2, 1),
        (2, 64, 32, 5, 3, 2), // s+p>=k: still fast path
        (2, 63, 16, 5, 3, 1), // s+p<k: wavefront (spatially coupled)
    ];
    for &(n, hw, ch, k, s, p) in shapes {
        let mut rng = Rng::new(1);
        let conv = Conv2d::new_submersive(k, ch, ch, s, p, false, &mut rng);
        let x = Tensor::randn(&[n, hw, hw, ch], 1.0, &mut rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &g);

        let fwd = bench(1, iters, || {
            std::hint::black_box(conv.forward(&x));
        });
        let vjp_in = bench(1, iters, || {
            std::hint::black_box(conv.vjp_input(&res, &g));
        });
        let vjp_w = bench(1, iters, || {
            std::hint::black_box(conv.vjp_params(&x, &g));
        });
        let vijp = bench(1, iters, || {
            std::hint::black_box(conv.vijp(&res, &h).unwrap());
        });
        println!(
            "{:<34} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>10.2}",
            format!("{n}x{hw}x{hw}x{ch} k{k}s{s}p{p}{}", if s + p >= k { "" } else { " (wave)" }),
            fwd.median_ms(),
            vjp_in.median_ms(),
            vjp_w.median_ms(),
            vijp.median_ms(),
            vijp.median / vjp_in.median
        );
    }

    // Ablation 1 (DESIGN.md §10): anchor placement. The h₁ seed
    // checkpoints the cotangent *after* the stride-2 entry conv (s²
    // smaller) vs naively at the upsample output.
    {
        use moonwalk::autodiff::{Moonwalk, MoonwalkOpts};
        use moonwalk::coordinator::sweep::measure_engine as me;
        use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
        use moonwalk::nn::MeanLoss;
        let spec = SubmersiveCnn2dSpec {
            input_hw: 64,
            channels: 32,
            depth: 4,
            ..Default::default()
        };
        let mut rng = Rng::new(0);
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[4, 64, 64, 3], 1.0, &mut rng);
        println!("\nablation — cotangent anchor placement (moonwalk, depth 4):");
        for (label, naive) in [("h1 seed (paper §4.3 variant)", false), ("naive (break-layer output)", true)] {
            let engine = Moonwalk::new(MoonwalkOpts {
                naive_anchor: naive,
                ..Default::default()
            });
            let (mem, time, _) = me(&engine, &net, &x, &MeanLoss, 1, iters.min(5)).unwrap();
            println!(
                "  {label:<30} peak={} median={:.2}ms",
                tracker::fmt_bytes(mem),
                time * 1e3
            );
        }
    }

    // Allocation churn on the end-to-end engines (the §Perf metric).
    println!("\nallocation churn (one gradient computation):");
    use moonwalk::autodiff::engine_by_name;
    use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
    use moonwalk::nn::MeanLoss;
    let spec = SubmersiveCnn2dSpec {
        input_hw: 64,
        channels: 32,
        depth: 4,
        ..Default::default()
    };
    let mut rng = Rng::new(0);
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[4, 64, 64, 3], 1.0, &mut rng);
    for name in ["backprop", "moonwalk"] {
        let engine = engine_by_name(name, 4, 0, 0).unwrap();
        let (_, prof) = tracker::measure(|| {
            engine
                .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
                .unwrap()
        });
        println!(
            "  {name:<10} allocs={:<6} peak={}",
            prof.allocs,
            tracker::fmt_bytes(prof.peak_extra_bytes)
        );
    }
}
