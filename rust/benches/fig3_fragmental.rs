//! Fig. 3 (paper §6.3): the non-submersive 1-D CNN with fragmental
//! gradient checkpointing. (a) memory vs depth at fixed B=4 — paper:
//! ~50% below Backprop; (b) runtime vs block size — bigger blocks mean
//! more recomputation. Also reproduces the max-trainable-depth table
//! under a fixed memory budget (paper: Backprop dies at ~10 layers,
//! ckpt ~16, Moonwalk B=16 trains 22).

use moonwalk::autodiff::engine_by_name;
use moonwalk::coordinator::sweep::{format_table, measure_engine, to_csv, SweepRow};
use moonwalk::model::{build_cnn1d_fragmental, FragmentalCnn1dSpec};
use moonwalk::nn::MeanLoss;
use moonwalk::tensor::{tracker, Tensor};
use moonwalk::util::Rng;

fn net_and_input(depth: usize) -> (moonwalk::model::Network, Tensor) {
    let spec = FragmentalCnn1dSpec {
        input_len: 512,
        channels: 64,
        depth,
        ..Default::default()
    };
    let mut rng = Rng::new(0);
    let net = build_cnn1d_fragmental(&spec, &mut rng);
    let x = Tensor::randn(&[4, 512, 3], 1.0, &mut rng);
    (net, x)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut rows = Vec::new();

    // (a) memory vs depth at fixed B=4.
    let depths: Vec<usize> = if quick { vec![2, 4] } else { vec![1, 2, 4, 6, 8] };
    for &depth in &depths {
        let (net, x) = net_and_input(depth);
        for (name, block) in [("backprop", 0usize), ("backprop_ckpt", 0), ("moonwalk_frag", 4)] {
            let engine = engine_by_name(name, block.max(4), 0, 0)?;
            let (mem, time, loss) =
                measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 1, if quick { 2 } else { 4 })?;
            rows.push(SweepRow {
                engine: engine.name(),
                depth,
                param: block,
                peak_mem_bytes: mem,
                median_time_s: time,
                loss,
            });
        }
    }
    print!("{}", format_table("Fig 3a — 1-D fragmental: memory vs depth (B=4)", &rows));
    let deepest = *depths.last().unwrap();
    let bp = rows.iter().find(|r| r.depth == deepest && r.engine == "backprop").unwrap();
    let fr = rows
        .iter()
        .find(|r| r.depth == deepest && r.engine.starts_with("moonwalk_frag"))
        .unwrap();
    println!(
        "\nheadline @ depth {deepest}: fragmental B=4 memory = {:.2}x backprop ({:.0}% saving; paper ~50%)\n",
        fr.peak_mem_bytes as f64 / bp.peak_mem_bytes as f64,
        100.0 * (1.0 - fr.peak_mem_bytes as f64 / bp.peak_mem_bytes as f64)
    );

    // (b) block-size <-> time trade-off at fixed depth.
    let mut rows_b = Vec::new();
    let (net, x) = net_and_input(4);
    for block in [4usize, 8, 16, 32] {
        let engine = engine_by_name("moonwalk_frag", block, 0, 0)?;
        let (mem, time, loss) =
            measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 1, if quick { 2 } else { 4 })?;
        rows_b.push(SweepRow {
            engine: engine.name(),
            depth: 4,
            param: block,
            peak_mem_bytes: mem,
            median_time_s: time,
            loss,
        });
    }
    print!("{}", format_table("Fig 3b — block size trade-off (depth 4)", &rows_b));

    // Max trainable depth under a fixed budget (paper's 24 GB analogue:
    // a budget calibrated to the depth-6 Backprop peak, mirroring the
    // paper's "backprop fails beyond 10 layers" setup).
    let budget = {
        let (net, x) = net_and_input(6);
        let engine = engine_by_name("backprop", 0, 0, 0)?;
        let (mem, _, _) = measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 0, 1)?;
        mem
    };
    println!("\nmax trainable depth under budget {}:", tracker::fmt_bytes(budget));
    for (name, block) in [("backprop", 0usize), ("backprop_ckpt", 0), ("moonwalk_frag", 16)] {
        let mut max_depth = 0;
        for depth in (2..=(if quick { 12 } else { 48 })).step_by(2) {
            let (net, x) = net_and_input(depth);
            let engine = engine_by_name(name, block.max(4), 0, 0)?;
            let (mem, _, _) = measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 0, 1)?;
            if mem <= budget {
                max_depth = depth;
            } else {
                break;
            }
        }
        println!("  {name:<16} (B={block:<2}) -> {max_depth} layers");
    }
    rows.extend(rows_b);
    std::fs::write("fig3_fragmental.csv", to_csv(&rows))?;
    println!("wrote fig3_fragmental.csv");
    Ok(())
}
