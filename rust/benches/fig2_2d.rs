//! Fig. 2 (paper §6.2): the fully parallel submersive 2-D CNN.
//! (a) peak memory vs depth; (b) wall-clock vs depth — for Backprop,
//! checkpointed Backprop and Moonwalk. Prints both series and writes
//! CSV next to the binary output.
//!
//! Paper reference (RTX 3090, 256×256×3→128ch, batch 128): Moonwalk cuts
//! peak memory ~30% (9.5→6.6 GB at 8 blocks) at comparable runtime.
//! This harness runs the same architecture family scaled for CPU
//! (64×64×3→32ch, batch 4); the claim under test is the *ratio* and the
//! curve shapes, not absolute bytes (DESIGN.md §2).

use moonwalk::autodiff::engine_by_name;
use moonwalk::coordinator::sweep::{format_table, measure_engine, to_csv, SweepRow};
use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
use moonwalk::nn::MeanLoss;
use moonwalk::tensor::Tensor;
use moonwalk::util::Rng;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let depths: Vec<usize> = if quick {
        vec![1, 2, 4]
    } else {
        vec![1, 2, 3, 4, 5, 6, 7, 8]
    };
    let engines = ["backprop", "backprop_ckpt", "moonwalk"];
    let mut rows = Vec::new();
    for &depth in &depths {
        let spec = SubmersiveCnn2dSpec {
            input_hw: 64,
            channels: 32,
            depth,
            ..Default::default()
        };
        let mut rng = Rng::new(0);
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[4, 64, 64, 3], 1.0, &mut rng);
        for name in engines {
            let engine = engine_by_name(name, 4, 0, 0)?;
            let (mem, time, loss) =
                measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 1, if quick { 2 } else { 5 })?;
            rows.push(SweepRow {
                engine: engine.name(),
                depth,
                param: 0,
                peak_mem_bytes: mem,
                median_time_s: time,
                loss,
            });
        }
    }
    print!("{}", format_table("Fig 2a/2b — 2-D submersive CNN: memory & time vs depth", &rows));

    // Headline ratio at max depth.
    let deepest = *depths.last().unwrap();
    let at = |e: &str| {
        rows.iter()
            .find(|r| r.depth == deepest && r.engine.starts_with(e))
            .unwrap()
    };
    let bp = at("backprop");
    let mw = at("moonwalk");
    println!(
        "\nheadline @ depth {deepest}: moonwalk memory = {:.2}x backprop ({:.0}% saving; paper ~30%), \
         time = {:.2}x backprop (paper: comparable)",
        mw.peak_mem_bytes as f64 / bp.peak_mem_bytes as f64,
        100.0 * (1.0 - mw.peak_mem_bytes as f64 / bp.peak_mem_bytes as f64),
        mw.median_time_s / bp.median_time_s
    );
    std::fs::write("fig2_2d.csv", to_csv(&rows))?;
    println!("wrote fig2_2d.csv");
    Ok(())
}
