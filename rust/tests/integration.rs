//! Cross-module integration: trainer + engines + planner + CLI-level
//! flows, and the measured-memory ordering claims of the paper.

use moonwalk::autodiff::{engine_by_name, GradEngine};
use moonwalk::coordinator::sweep::measure_engine;
use moonwalk::coordinator::{Optimizer, OptimizerKind, SyntheticSpec, TextureDataset, Trainer};
use moonwalk::memsim;
use moonwalk::model::config::Config;
use moonwalk::model::{build_cnn1d_fragmental, build_cnn2d, FragmentalCnn1dSpec, SubmersiveCnn2dSpec};
use moonwalk::nn::MeanLoss;
use moonwalk::tensor::Tensor;
use moonwalk::util::json::Json;
use moonwalk::util::Rng;

#[test]
fn measured_memory_moonwalk_below_backprop_2d() {
    // The Fig.-2a headline on the scaled config: ≥20% peak reduction.
    let spec = SubmersiveCnn2dSpec {
        input_hw: 64,
        channels: 32,
        depth: 4,
        ..Default::default()
    };
    let mut rng = Rng::new(0);
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[4, 64, 64, 3], 1.0, &mut rng);
    let bp = engine_by_name("backprop", 0, 0, 0).unwrap();
    let mw = engine_by_name("moonwalk", 0, 0, 0).unwrap();
    let (bp_mem, _, bp_loss) = measure_engine(bp.as_ref(), &net, &x, &MeanLoss, 0, 1).unwrap();
    let (mw_mem, _, mw_loss) = measure_engine(mw.as_ref(), &net, &x, &MeanLoss, 0, 1).unwrap();
    assert!((bp_loss - mw_loss).abs() < 1e-5);
    let ratio = mw_mem as f64 / bp_mem as f64;
    assert!(
        ratio < 0.8,
        "moonwalk should save ≥20% memory (got ratio {ratio:.2})"
    );
}

#[test]
fn measured_memory_fragmental_below_backprop_1d() {
    // Fig.-3a headline: fragmental B=4 ≈ half of Backprop.
    let spec = FragmentalCnn1dSpec {
        input_len: 512,
        channels: 64,
        depth: 4,
        ..Default::default()
    };
    let mut rng = Rng::new(1);
    let net = build_cnn1d_fragmental(&spec, &mut rng);
    let x = Tensor::randn(&[4, 512, 3], 1.0, &mut rng);
    let bp = engine_by_name("backprop", 0, 0, 0).unwrap();
    let fr = engine_by_name("moonwalk_frag", 4, 0, 0).unwrap();
    let (bp_mem, _, _) = measure_engine(bp.as_ref(), &net, &x, &MeanLoss, 0, 1).unwrap();
    let (fr_mem, _, _) = measure_engine(fr.as_ref(), &net, &x, &MeanLoss, 0, 1).unwrap();
    let ratio = fr_mem as f64 / bp_mem as f64;
    assert!(
        ratio < 0.65,
        "fragmental B=4 should save ≥35% (paper ~50%), got ratio {ratio:.2}"
    );
}

#[test]
fn planner_agrees_with_measurement_ordering() {
    // The memsim model must rank Backprop vs Moonwalk the same way the
    // allocation tracker does.
    let spec = SubmersiveCnn2dSpec {
        input_hw: 32,
        channels: 16,
        depth: 4,
        ..Default::default()
    };
    let mut rng = Rng::new(2);
    let net = build_cnn2d(&spec, &mut rng);
    let in_shape = vec![2usize, 32, 32, 3];
    let costs = memsim::profile(&net, &in_shape).unwrap();
    let pred_bp = memsim::predict_memory(&memsim::Method::Backprop, &costs);
    let pred_mw = memsim::predict_memory(&memsim::Method::Moonwalk, &costs);
    let x = Tensor::randn(&in_shape, 1.0, &mut rng);
    let bp = engine_by_name("backprop", 0, 0, 0).unwrap();
    let mw = engine_by_name("moonwalk", 0, 0, 0).unwrap();
    let (meas_bp, _, _) = measure_engine(bp.as_ref(), &net, &x, &MeanLoss, 0, 1).unwrap();
    let (meas_mw, _, _) = measure_engine(mw.as_ref(), &net, &x, &MeanLoss, 0, 1).unwrap();
    assert_eq!(pred_mw < pred_bp, meas_mw < meas_bp, "model/measurement rank");
    // And the predictions should be within 2x of measurements.
    for (pred, meas, what) in [(pred_bp, meas_bp, "bp"), (pred_mw, meas_mw, "mw")] {
        let ratio = pred as f64 / meas as f64;
        assert!(
            (0.4..2.5).contains(&ratio),
            "{what}: model {pred} vs measured {meas} (ratio {ratio:.2})"
        );
    }
}

#[test]
fn trainer_moonwalk_learns_texture_task() {
    let mut rng = Rng::new(3);
    let spec = SubmersiveCnn2dSpec {
        input_hw: 16,
        channels: 8,
        depth: 2,
        classes: 3,
        cin: 2,
        ..Default::default()
    };
    let mut net = build_cnn2d(&spec, &mut rng);
    let data = TextureDataset::generate(
        SyntheticSpec {
            classes: 3,
            hw: 16,
            cin: 2,
            noise: 0.2,
            seed: 3,
        },
        90,
    );
    let (train, test) = data.split(0.2);
    let engine = engine_by_name("moonwalk", 0, 0, 0).unwrap();
    let opt = Optimizer::new(OptimizerKind::Adam, 3e-3, &net, true);
    let mut trainer = Trainer::new(&mut net, engine.as_ref(), opt);
    let rep = trainer
        .train(&train, &test, 6, 60, &mut Rng::new(4), None)
        .unwrap();
    assert!(
        rep.test_accuracy > 0.5,
        "moonwalk-trained classifier should beat chance by a margin: {}",
        rep.test_accuracy
    );
}

#[test]
fn config_roundtrip_drives_engine_selection() {
    let j = Json::parse(
        r#"{"arch":"cnn1d","engine":"moonwalk_frag","block":8,"depth":2,
            "channels":8,"input_len":32,"batch":2}"#,
    )
    .unwrap();
    let cfg = Config::from_json(&j).unwrap();
    let mut rng = Rng::new(0);
    let net = cfg.build_network(&mut rng);
    let engine = engine_by_name(&cfg.engine, cfg.block, cfg.checkpoint_every, cfg.seed).unwrap();
    let x = Tensor::randn(&cfg.input_shape(), 1.0, &mut rng);
    let result = engine.compute(&net, &x, &MeanLoss).unwrap();
    assert!(result.loss.is_finite());
    assert!(result.grads.iter().any(|g| !g.is_empty()));
}

#[test]
fn planner_end_to_end_under_budget() {
    let spec = SubmersiveCnn2dSpec {
        input_hw: 32,
        channels: 16,
        depth: 4,
        ..Default::default()
    };
    let mut rng = Rng::new(5);
    let net = build_cnn2d(&spec, &mut rng);
    let in_shape = vec![2usize, 32, 32, 3];
    let costs = memsim::profile(&net, &in_shape).unwrap();
    let bp = memsim::predict_memory(&memsim::Method::Backprop, &costs);
    // Budget below Backprop: the planner must pick something else, and
    // the chosen engine must actually run and produce exact grads.
    let (method, mem, _) = memsim::plan(&costs, bp - 1, true, 32 * 32 * 3).unwrap();
    assert!(mem < bp);
    let engine = engine_by_name(method.engine_name(), 8, 0, 0).unwrap();
    let x = Tensor::randn(&in_shape, 1.0, &mut rng);
    let chosen = engine.compute(&net, &x, &MeanLoss).unwrap();
    let reference = moonwalk::autodiff::Backprop.compute(&net, &x, &MeanLoss).unwrap();
    for (a, b) in reference.grads.iter().flatten().zip(chosen.grads.iter().flatten()) {
        assert!(moonwalk::tensor::rel_err(b, a) < 1e-2);
    }
}
