//! PJRT round-trip tests: load the AOT artifacts (`make artifacts`),
//! compile them on the PJRT CPU client and check their numerics against
//! the native Rust layer library — the L1/L2/L3 composition proof.
//!
//! Skipped (with a notice) when `artifacts/` has not been built.

use moonwalk::nn::{Conv2d, Layer, LeakyRelu, ResidualKind};
use moonwalk::runtime::PjrtRuntime;
use moonwalk::tensor::{assert_close, Tensor};
use moonwalk::util::Rng;
use std::path::Path;

fn runtime() -> Option<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(PjrtRuntime::load(&dir).expect("artifact compilation"))
}

/// Build the same conv layer the artifacts were lowered for.
fn conv_from_manifest(rt: &PjrtRuntime, seed: u64) -> (Conv2d, usize, usize) {
    let cfg = &rt.manifest.config;
    let ch = cfg.req_usize("channels").unwrap();
    let k = cfg.req_usize("k").unwrap();
    let s = cfg.req_usize("stride").unwrap();
    let p = cfg.req_usize("pad").unwrap();
    let batch = cfg.req_usize("batch").unwrap();
    let hw = cfg.req_usize("hw").unwrap();
    let mut rng = Rng::new(seed);
    (
        Conv2d::new_submersive(k, ch, ch, s, p, false, &mut rng),
        batch,
        hw,
    )
}

#[test]
fn conv_fwd_matches_native() {
    let Some(rt) = runtime() else { return };
    let (conv, batch, hw) = conv_from_manifest(&rt, 1);
    let mut rng = Rng::new(2);
    let x = Tensor::randn(&[batch, hw, hw, conv.cin], 1.0, &mut rng);
    let y_native = conv.forward(&x);
    let y_pjrt = rt.execute1("conv0_fwd", &[&x, &conv.w]).unwrap();
    assert_close(&y_pjrt, &y_native, 1e-4, "PJRT conv fwd vs native");
}

#[test]
fn conv_vijp_pallas_matches_native() {
    // The paper's operator: the Pallas Alg.-2 kernel (lowered through
    // interpret mode into the artifact) must agree with the Rust
    // elimination.
    let Some(rt) = runtime() else { return };
    let (conv, batch, hw) = conv_from_manifest(&rt, 3);
    let mut rng = Rng::new(4);
    let x = Tensor::randn(&[batch, hw, hw, conv.cin], 1.0, &mut rng);
    let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
    let hprime = Tensor::randn(y.shape(), 1.0, &mut rng);
    let h = conv.vjp_input(&res, &hprime);
    let native = conv.vijp(&res, &h).unwrap();
    let pjrt = rt.execute1("conv0_vijp", &[&h, &conv.w]).unwrap();
    assert_close(&pjrt, &native, 1e-3, "PJRT Pallas vijp vs native");
    assert_close(&pjrt, &hprime, 1e-3, "PJRT Pallas vijp right-inverse");
}

#[test]
fn conv_vjps_match_native() {
    let Some(rt) = runtime() else { return };
    let (conv, batch, hw) = conv_from_manifest(&rt, 5);
    let mut rng = Rng::new(6);
    let x = Tensor::randn(&[batch, hw, hw, conv.cin], 1.0, &mut rng);
    let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
    let g = Tensor::randn(y.shape(), 1.0, &mut rng);
    let h_native = conv.vjp_input(&res, &g);
    let h_pjrt = rt.execute1("conv0_vjp_in", &[&g, &conv.w]).unwrap();
    assert_close(&h_pjrt, &h_native, 1e-4, "PJRT conv vjp_in");
    let dw_native = conv.vjp_params(&x, &g);
    let dw_pjrt = rt.execute1("conv0_vjp_w", &[&x, &g]).unwrap();
    assert_close(&dw_pjrt, &dw_native[0], 1e-3, "PJRT conv vjp_w");
}

#[test]
fn lrelu_ops_match_native() {
    let Some(rt) = runtime() else { return };
    let cfg = &rt.manifest.config;
    let alpha = cfg.req_f64("alpha").unwrap() as f32;
    let op = rt.manifest.op("lrelu0_fwd").unwrap().clone();
    let shape = op.inputs[0].clone();
    let mut rng = Rng::new(7);
    let x = Tensor::randn(&shape, 1.0, &mut rng);
    let lrelu = LeakyRelu::new(alpha);
    let y_native = lrelu.forward(&x);
    let y_pjrt = rt.execute1("lrelu0_fwd", &[&x]).unwrap();
    assert_close(&y_pjrt, &y_native, 1e-5, "PJRT lrelu fwd");

    let h = Tensor::randn(&shape, 1.0, &mut rng);
    let (_, res) = lrelu.forward_res(&x, ResidualKind::Minimal);
    let vijp_native = lrelu.vijp(&res, &h).unwrap();
    let vijp_pjrt = rt.execute1("lrelu0_vijp", &[&x, &h]).unwrap();
    assert_close(&vijp_pjrt, &vijp_native, 1e-4, "PJRT lrelu vijp");
}

#[test]
fn loss_grad_shapes_and_values() {
    let Some(rt) = runtime() else { return };
    let cfg = &rt.manifest.config;
    let batch = cfg.req_usize("batch").unwrap();
    let classes = cfg.req_usize("classes").unwrap();
    let mut rng = Rng::new(8);
    let logits = Tensor::randn(&[batch, classes], 1.0, &mut rng);
    let mut onehot = Tensor::zeros(&[batch, classes]);
    for i in 0..batch {
        let idx = i * classes + (i % classes);
        onehot.data_mut()[idx] = 1.0;
    }
    let out = rt.execute("loss_grad", &[&logits, &onehot]).unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].len(), 1);
    // Compare against the native softmax cross-entropy.
    let targets: Vec<usize> = (0..batch).map(|i| i % classes).collect();
    let native = moonwalk::nn::SoftmaxCrossEntropy::new(targets);
    use moonwalk::nn::Loss;
    assert!((out[0].data()[0] - native.value(&logits)).abs() < 1e-4);
    assert_close(&out[1], &native.grad(&logits), 1e-4, "PJRT loss grad");
}

#[test]
fn shape_mismatch_rejected() {
    let Some(rt) = runtime() else { return };
    let bad = Tensor::zeros(&[1, 2, 3]);
    assert!(rt.execute("conv0_fwd", &[&bad, &bad]).is_err());
    assert!(rt.execute("nonexistent_op", &[]).is_err());
}
