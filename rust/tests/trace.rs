//! Tracing subsystem contract (ISSUE 8):
//!
//! 1. **Span algebra** — spans recorded across a real engine run are
//!    balanced and properly nested per thread (no partial interval
//!    overlap; phase spans appear exactly once, per-layer spans once
//!    per layer), and carry monotone memory samples from the tracker.
//! 2. **Chrome roundtrip** — `--trace`-style capture via
//!    [`moonwalk::obs::export::set_trace_path`] + `finish()` writes a
//!    well-formed `{"traceEvents": […]}` JSON that this repo's own
//!    parser accepts, with rebased timestamps and the documented event
//!    fields (`ph`, `pid`, `tid`, `ts`).
//! 3. **Multi-process merge** — a capture spanning the unix-socket
//!    transport folds worker-subprocess spool files into the single
//!    coordinator trace: events from ≥ 2 distinct pids, including the
//!    workers' `worker.step` spans.
//! 4. **Spool hygiene** — spool files stamped with a different run id
//!    (a crashed earlier incarnation, an orphaned worker writing late)
//!    are skipped by the merge instead of leaking into the trace.
//! 5. **Determinism** — the full `EXACT_ENGINES` grid produces
//!    bit-identical losses and parameter gradients with span capture
//!    on vs off (the never-perturb contract of ARCHITECTURE.md §2.6).
//!
//! Span recording, the ring registry, and the trace-capture path are
//! process-global, so every test here serializes through one mutex and
//! restores the disabled state before releasing it.

use std::sync::Mutex;

use moonwalk::autodiff::{engine_by_name, EXACT_ENGINES};
use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
use moonwalk::nn::MeanLoss;
use moonwalk::obs::{export, span};
use moonwalk::tensor::Tensor;
use moonwalk::util::json::Json;
use moonwalk::util::Rng;

/// Serializes every test: span state and the capture path are global.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn trace_lock() -> std::sync::MutexGuard<'static, ()> {
    match TRACE_LOCK.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Tiny depth-3 submersive CNN + input, deterministic per seed.
fn tiny_net(seed: u64) -> (moonwalk::model::Network, Tensor) {
    let spec = SubmersiveCnn2dSpec {
        input_hw: 16,
        channels: 5,
        depth: 3,
        cin: 2,
        classes: 3,
        ..Default::default()
    };
    let mut rng = Rng::new(seed);
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[2, 16, 16, 2], 1.0, &mut rng);
    (net, x)
}

/// One streamed gradient computation, collecting loss + per-layer grads.
fn run_engine(
    engine: &dyn moonwalk::autodiff::GradEngine,
    net: &moonwalk::model::Network,
    x: &Tensor,
) -> (f32, Vec<Vec<Tensor>>) {
    let mut grads: Vec<Vec<Tensor>> = (0..net.depth()).map(|_| Vec::new()).collect();
    let loss = engine
        .compute_streaming(net, x, &MeanLoss, &mut |li, g| grads[li] = g)
        .expect("engine run");
    (loss, grads)
}

// ---------------------------------------------------------------------------
// 1. Span algebra on a real engine run
// ---------------------------------------------------------------------------

#[test]
fn spans_balance_and_nest_across_engine_run() {
    let _g = trace_lock();
    let (net, x) = tiny_net(11);
    let engine = engine_by_name("moonwalk", 4, 0, 0).unwrap();
    let _ = span::drain_all(); // start from empty rings
    span::set_enabled(true);
    let _ = run_engine(engine.as_ref(), &net, &x);
    span::set_enabled(false);
    let threads = span::drain_all();

    let mut phase_counts = [0usize; 3];
    let mut fwd_layers = 0usize;
    for t in &threads {
        assert_eq!(t.dropped, 0, "tiny run must not overflow the ring");
        // No partial interval overlap on any one thread: spans are
        // strictly LIFO, so any two either nest or are disjoint.
        let spans: Vec<_> = t.events.iter().filter(|e| !e.instant).collect();
        for (i, a) in spans.iter().enumerate() {
            let (a0, a1) = (a.start_us, a.start_us + a.dur_us);
            for b in spans.iter().skip(i + 1) {
                let (b0, b1) = (b.start_us, b.start_us + b.dur_us);
                let disjoint = b0 >= a1 || a0 >= b1;
                let nested = (b0 >= a0 && b1 <= a1) || (a0 >= b0 && a1 <= b1);
                assert!(
                    disjoint || nested,
                    "partial overlap on thread {}: {} [{a0},{a1}] vs {} [{b0},{b1}]",
                    t.tid,
                    a.name,
                    b.name
                );
            }
        }
        for e in &t.events {
            match e.name {
                "moonwalk.phase1" => phase_counts[0] += 1,
                "moonwalk.phase2" => phase_counts[1] += 1,
                "moonwalk.phase3" => phase_counts[2] += 1,
                "phase1.forward" => fwd_layers += 1,
                _ => {}
            }
        }
    }
    // Balanced phase structure: each phase span exactly once, one
    // forward span per layer.
    assert_eq!(phase_counts, [1, 1, 1]);
    assert_eq!(fwd_layers, net.depth());
}

#[test]
fn disabled_spans_record_nothing_across_engine_run() {
    let _g = trace_lock();
    let (net, x) = tiny_net(12);
    let engine = engine_by_name("moonwalk", 4, 0, 0).unwrap();
    span::set_enabled(false);
    let _ = span::drain_all();
    let _ = run_engine(engine.as_ref(), &net, &x);
    let total: usize = span::drain_all().iter().map(|t| t.events.len()).sum();
    assert_eq!(total, 0, "disabled tracing must not record events");
}

// ---------------------------------------------------------------------------
// 2. Chrome trace-event roundtrip
// ---------------------------------------------------------------------------

#[test]
fn chrome_trace_roundtrip_single_process() {
    let _g = trace_lock();
    let path = std::env::temp_dir().join(format!(
        "moonwalk_trace_roundtrip_{}.trace.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let _ = span::drain_all();
    export::set_trace_path(path.to_str().unwrap()).unwrap();
    assert!(export::trace_active());
    assert!(span::enabled(), "capture must enable span recording");

    let (net, x) = tiny_net(13);
    let engine = engine_by_name("moonwalk", 4, 0, 0).unwrap();
    let _ = run_engine(engine.as_ref(), &net, &x);

    let written = export::finish().unwrap().expect("capture was active");
    assert_eq!(written, path);
    assert!(!export::trace_active(), "finish consumes the capture");
    assert!(!span::enabled(), "finish disables span recording");
    let spool = std::path::PathBuf::from(format!("{}.workers", path.display()));
    assert!(!spool.exists(), "finish removes the worker spool");

    let text = std::fs::read_to_string(&path).unwrap();
    let json = Json::parse(&text).expect("trace is valid JSON");
    let events = json.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty());
    let mut min_ts = f64::INFINITY;
    let mut names = std::collections::BTreeSet::new();
    for e in &events {
        let ph = e.get("ph").as_str().expect("every event has ph");
        assert!(e.get("pid").as_usize().is_some(), "every event has pid");
        if let Some(name) = e.get("name").as_str() {
            names.insert(name.to_string());
        }
        if let Some(ts) = e.get("ts").as_f64() {
            assert!(ts >= 0.0, "timestamps rebased to the trace start");
            min_ts = min_ts.min(ts);
        }
        if ph == "X" {
            assert!(e.get("dur").as_f64().is_some(), "spans carry dur");
        }
    }
    assert_eq!(min_ts, 0.0, "earliest event sits at t=0");
    for want in [
        "moonwalk.phase1",
        "moonwalk.phase2",
        "moonwalk.phase3",
        "phase2.cotangent",
        "mem.current",
        "process_name",
    ] {
        assert!(names.contains(want), "trace is missing {want}");
    }
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// 3. Multi-process merge through the unix transport
// ---------------------------------------------------------------------------

#[test]
fn chrome_trace_merges_unix_worker_processes() {
    use moonwalk::distributed::transport::{
        EngineSpec, LossSpec, ShardSpec, Transport, UnixTransport, UnixTransportOpts,
    };
    use moonwalk::distributed::{split_batch, ReduceOp};
    use moonwalk::model::config::Config;

    let _g = trace_lock();
    let path = std::env::temp_dir().join(format!(
        "moonwalk_trace_merge_{}.trace.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let _ = span::drain_all();
    // The capture must be live *before* spawn so the workers inherit
    // the spool directory through the environment.
    export::set_trace_path(path.to_str().unwrap()).unwrap();

    let cfg = Config::from_json(
        &Json::parse(
            r#"{"arch": "cnn2d", "depth": 2, "channels": 5, "input_hw": 16,
                "cin": 2, "classes": 4, "seed": 9}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let mut rng = Rng::new(cfg.seed);
    let net = cfg.build_network(&mut rng);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let engine = engine_by_name("moonwalk", cfg.block, cfg.checkpoint_every, cfg.seed).unwrap();

    let mut opts = UnixTransportOpts::new(2, cfg.to_json().to_string(), EngineSpec::new("moonwalk"));
    opts.worker_bin = Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_moonwalk")));
    let mut transport = UnixTransport::spawn(opts).expect("spawn unix transport");
    transport.broadcast(&net).unwrap();
    let xs = split_batch(&x, 2).unwrap();
    let shards: Vec<ShardSpec<'_>> = xs
        .iter()
        .map(|x| ShardSpec {
            x,
            loss: LossSpec::Mean,
        })
        .collect();
    transport
        .step(&net, engine.as_ref(), &shards, ReduceOp::Mean, &|_, g| {
            drop(g)
        })
        .unwrap();
    // Shutdown waits for the workers, whose exit path writes the spool
    // files the merge below folds in.
    drop(transport);

    let written = export::finish().unwrap().expect("capture was active");
    let text = std::fs::read_to_string(&written).unwrap();
    let json = Json::parse(&text).expect("merged trace is valid JSON");
    let events = json.get("traceEvents").as_arr().expect("traceEvents");
    let mut pids = std::collections::BTreeSet::new();
    let mut worker_step_pids = std::collections::BTreeSet::new();
    for e in &events {
        let pid = e.get("pid").as_usize().expect("pid");
        pids.insert(pid);
        if e.get("name").as_str() == Some("worker.step") {
            worker_step_pids.insert(pid);
        }
    }
    let own = std::process::id() as usize;
    assert!(
        pids.len() >= 3,
        "expected coordinator + 2 worker pids, got {pids:?}"
    );
    assert!(pids.contains(&own), "coordinator events present");
    assert_eq!(
        worker_step_pids.len(),
        2,
        "each worker contributes its worker.step span"
    );
    assert!(
        !worker_step_pids.contains(&own),
        "worker.step spans come from the subprocesses"
    );
    let _ = std::fs::remove_file(&written);
}

// ---------------------------------------------------------------------------
// 4. Stale spool files from other runs never leak into a merge
// ---------------------------------------------------------------------------

#[test]
fn stale_spool_files_from_other_runs_are_not_merged() {
    let _g = trace_lock();
    let path = std::env::temp_dir().join(format!(
        "moonwalk_trace_stale_{}.trace.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let _ = span::drain_all();
    export::set_trace_path(path.to_str().unwrap()).unwrap();
    // A crashed earlier incarnation left a spool file behind: same
    // naming shape, but stamped with a run id this capture never
    // minted.
    let spool = std::path::PathBuf::from(format!("{}.workers", path.display()));
    std::fs::write(
        spool.join("worker-0-4242-0-0.trace.json"),
        r#"{"traceEvents": [{"name": "stale.marker", "ph": "X",
            "pid": 4242, "tid": 1, "ts": 5, "dur": 5}],
            "droppedEvents": 0}"#,
    )
    .unwrap();
    span::instant("live.marker", None);
    let written = export::finish().unwrap().expect("capture was active");
    let json = Json::parse(&std::fs::read_to_string(&written).unwrap()).unwrap();
    let names: std::collections::BTreeSet<String> = json
        .get("traceEvents")
        .as_arr()
        .expect("traceEvents")
        .iter()
        .filter_map(|e| e.get("name").as_str().map(str::to_string))
        .collect();
    assert!(
        names.contains("live.marker"),
        "this run's own events merge: {names:?}"
    );
    assert!(
        !names.contains("stale.marker"),
        "a stale spool file's events must not leak into the merge"
    );
    assert!(!spool.exists(), "finish still removes the spool");
    let _ = std::fs::remove_file(&written);
}

// ---------------------------------------------------------------------------
// 5. Tracing never perturbs determinism
// ---------------------------------------------------------------------------

#[test]
fn exact_engine_grid_bit_identical_tracing_on_vs_off() {
    let _g = trace_lock();
    let (net, x) = tiny_net(14);
    for name in EXACT_ENGINES {
        let engine = engine_by_name(name, 4, 2, 0).unwrap();
        span::set_enabled(false);
        let (loss_off, grads_off) = run_engine(engine.as_ref(), &net, &x);
        span::set_enabled(true);
        let (loss_on, grads_on) = run_engine(engine.as_ref(), &net, &x);
        span::set_enabled(false);
        let _ = span::drain_all();
        assert_eq!(
            loss_off.to_bits(),
            loss_on.to_bits(),
            "{name}: loss must be bit-identical with tracing on"
        );
        assert_eq!(grads_off.len(), grads_on.len());
        for (li, (ga, gb)) in grads_off.iter().zip(&grads_on).enumerate() {
            assert_eq!(ga.len(), gb.len(), "{name} layer {li}: grad arity");
            for (pi, (ta, tb)) in ga.iter().zip(gb).enumerate() {
                assert_eq!(ta.shape(), tb.shape());
                for (va, vb) in ta.data().iter().zip(tb.data()) {
                    assert_eq!(
                        va.to_bits(),
                        vb.to_bits(),
                        "{name} layer {li} param {pi}: gradient bits differ with tracing on"
                    );
                }
            }
        }
    }
}
