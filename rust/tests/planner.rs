//! Budgeted per-layer execution planner contract (ISSUE 5):
//!
//! 1. **Probe fidelity** — the calibration probe's byte counts are
//!    exactly what the real residual/fragment objects register with the
//!    allocation tracker (verified against live `tracker::current()`
//!    deltas under the measurement lock).
//! 2. **Budget invariants** — a compiled plan's conservative peak never
//!    exceeds its budget; tightening the budget never increases the
//!    selected plan's predicted bytes (monotonicity); randomized nets
//!    always produce *valid* plans (chain-state legality, every
//!    parameterized layer anchored); infeasible budgets err.
//! 3. **Engine equivalence** — `PlannedEngine` under a mid budget
//!    matches Backprop across the threads {1,4} × replicas {1,2} grid
//!    (loss ≤ 1e-5, grads ≤ 5e-3 — the repo's cross-engine norm), is
//!    1e-5-equivalent to itself across thread counts, and with an
//!    unbounded budget is **bit-identical** to Backprop.
//! 4. **Measured budget respect** — executing a plan compiled for a
//!    budget midway between the pure-Moonwalk and Backprop peaks keeps
//!    the *measured* tracker peak at or under the budget, end to end
//!    (the `--budget` knob's contract).
//!
//! The pool thread count is process-global, so thread-pinning tests
//! serialize through a local mutex (same pattern as the other suites).

use std::sync::Mutex;

use moonwalk::autodiff::{Backprop, GradEngine, PlannedEngine};
use moonwalk::distributed::{split_batch, ReduceOp, ReplicaGroup, Shard};
use moonwalk::memsim;
use moonwalk::model::{
    build_cnn1d_fragmental, build_cnn2d, FragmentalCnn1dSpec, Network, SubmersiveCnn2dSpec,
};
use moonwalk::nn::{MeanLoss, ResidualKind};
use moonwalk::plan::{self, ResidualTier, Strategy};
use moonwalk::runtime::pool;
use moonwalk::tensor::{rel_err, tracker, Tensor};
use moonwalk::util::Rng;

/// Serializes the tests that pin the (process-global) pool thread count.
static THREAD_PIN: Mutex<()> = Mutex::new(());

fn pin_lock() -> std::sync::MutexGuard<'static, ()> {
    match THREAD_PIN.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn cnn2d(seed: u64, depth: usize, channels: usize) -> Network {
    let mut rng = Rng::new(seed);
    build_cnn2d(
        &SubmersiveCnn2dSpec {
            input_hw: 16,
            depth,
            channels,
            cin: 2,
            classes: 3,
            ..Default::default()
        },
        &mut rng,
    )
}

fn cnn1d(seed: u64, depth: usize, channels: usize, len: usize) -> Network {
    let mut rng = Rng::new(seed);
    build_cnn1d_fragmental(
        &FragmentalCnn1dSpec {
            input_len: len,
            channels,
            depth,
            classes: 3,
            ..Default::default()
        },
        &mut rng,
    )
}

// ---------------------------------------------------------------------------
// 1. Probe fidelity against the live tracker
// ---------------------------------------------------------------------------

/// The probe's per-layer byte counts must equal live tracker deltas
/// while the same residual objects are held — i.e. the probe reports
/// exactly what the engines' Phase I/II footprints will register.
/// `tracker::current()` is process-global and other tests in this
/// binary allocate concurrently, so a polluted walk is retried; a
/// genuine probe/tracker divergence fails on every attempt.
#[test]
fn probe_bytes_match_live_tracker_deltas() {
    let net = cnn1d(0, 2, 8, 64);
    let in_shape = [2usize, 64, 3];
    let probes = plan::probe_network(&net, &in_shape, plan::DEFAULT_FRAG_BLOCKS).unwrap();
    let walk = || -> Result<(), String> {
        let _lock = tracker::measure_lock();
        let mut x = Tensor::zeros(&in_shape);
        for (layer, p) in net.layers.iter().zip(&probes) {
            // Minimal-residual bytes: tracker delta of holding (y, res)
            // minus the output tensor itself.
            let live0 = tracker::current();
            let (y, res) = layer.forward_res(&x, ResidualKind::Minimal);
            let delta = tracker::current().wrapping_sub(live0);
            if delta.wrapping_sub(y.bytes()) != p.measured_mx {
                return Err(format!("{}: probe mx vs tracker delta", p.cost.name));
            }
            assert_eq!(y.bytes(), p.measured_act, "{}: act bytes", p.cost.name);
            drop(res);
            // Fragment candidates: tracker delta of holding the capture.
            for f in &p.fragments {
                let live0 = tracker::current();
                let h = Tensor::zeros(y.shape());
                let frag = layer.fragment_capture(&h, f.block).unwrap();
                let delta = tracker::current().wrapping_sub(live0).wrapping_sub(h.bytes());
                if delta != f.bytes {
                    return Err(format!("{} B={}: fragment bytes", p.cost.name, f.block));
                }
                drop(frag);
            }
            x = y;
        }
        Ok(())
    };
    let mut last = String::new();
    for _ in 0..5 {
        match walk() {
            Ok(()) => return,
            Err(e) => last = e,
        }
    }
    panic!("tracker deltas never matched the probe: {last}");
}

/// Measured-vs-analytic reconciliation: the probe carries memsim's
/// `LayerCost` beside its measurements; residual tiers agree exactly and
/// fragment bytes agree whenever the block divides the length (the
/// analytic formula ignores tail-block rounding — which is exactly why
/// the planner uses the measured number).
#[test]
fn probe_reconciles_with_analytic_model() {
    // Length 60 with block 8: 60/8 = 7.5 blocks -> the real capture
    // rounds up, the analytic formula doesn't.
    let net = cnn1d(1, 2, 6, 60);
    let probes = plan::probe_network(&net, &[1, 60, 3], &[8, 16]).unwrap();
    for p in &probes {
        assert_eq!(p.measured_mx, p.cost.mx);
        assert_eq!(p.measured_m_theta, p.cost.m_theta);
        assert_eq!(p.measured_act, p.cost.act_bytes);
        for f in &p.fragments {
            assert!(
                f.bytes >= f.predicted_bytes,
                "{} B={}: measured {} < analytic {}",
                p.cost.name,
                f.block,
                f.bytes,
                f.predicted_bytes
            );
        }
    }
    // At least one tail-rounded candidate actually diverges, proving the
    // reconciliation is not vacuous.
    assert!(
        probes
            .iter()
            .flat_map(|p| &p.fragments)
            .any(|f| f.bytes > f.predicted_bytes),
        "expected a tail-block divergence at length 60"
    );
}

// ---------------------------------------------------------------------------
// 2. Budget invariants on randomized nets
// ---------------------------------------------------------------------------

/// Tighter budget ⇒ never more predicted bytes; every selected plan
/// respects its budget and validates.
#[test]
fn budget_monotonicity_randomized() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed);
        let (net, in_shape): (Network, Vec<usize>) = if rng.bernoulli(0.5) {
            (
                cnn2d(seed, rng.int_range(1, 4), rng.int_range(3, 6)),
                vec![rng.int_range(1, 3), 16, 16, 2],
            )
        } else {
            let len = 32 * rng.int_range(1, 3);
            (
                cnn1d(seed, rng.int_range(1, 4), rng.int_range(4, 9), len),
                vec![rng.int_range(1, 3), len, 3],
            )
        };
        let probes = plan::probe_network(&net, &in_shape, plan::DEFAULT_FRAG_BLOCKS).unwrap();
        let frontier = plan::build_frontier(&probes);
        let lo = frontier.min_peak();
        let hi = frontier.max_useful_peak().max(lo + 1);
        let mut last = 0usize;
        for i in 0..=6 {
            let budget = lo + (hi - lo) * i / 6;
            let compiled = frontier.select(&probes, Some(budget)).unwrap();
            assert!(
                compiled.conservative_peak <= budget,
                "seed {seed}: {} > budget {budget}",
                compiled.conservative_peak
            );
            assert!(
                compiled.conservative_peak >= last,
                "seed {seed}: monotonicity violated"
            );
            last = compiled.conservative_peak;
            plan::validate(&compiled.decisions, &probes).unwrap();
            // Every parameterized layer is anchored.
            let mut chain_ok = true;
            for (d, p) in compiled.decisions.iter().zip(&probes) {
                if p.cost.d_params > 0 {
                    assert!(
                        !matches!(d.strategy, Strategy::Residual(ResidualTier::Minimal)),
                        "seed {seed}: parameterized layer skipped"
                    );
                    if matches!(d.strategy, Strategy::Vijp | Strategy::Fragment { .. }) {
                        assert!(chain_ok, "seed {seed}: chain-dependent strategy off-chain");
                    }
                }
                chain_ok = !matches!(d.strategy, Strategy::Residual(ResidualTier::Minimal));
            }
        }
        // Far-infeasible budget errs, naming the minimum.
        let err = frontier.select(&probes, Some(lo / 64)).unwrap_err();
        assert!(err.to_string().contains("minimum achievable"));
    }
}

// ---------------------------------------------------------------------------
// 3. PlannedEngine equivalence grid
// ---------------------------------------------------------------------------

/// Mid-budget helper: midway between the cheapest feasible plan and
/// Backprop's predicted peak for the probed shape.
fn mid_budget(net: &Network, in_shape: &[usize]) -> usize {
    let probes = plan::probe_network(net, in_shape, plan::DEFAULT_FRAG_BLOCKS).unwrap();
    let costs: Vec<memsim::LayerCost> = probes.iter().map(|p| p.cost.clone()).collect();
    let frontier = plan::build_frontier(&probes);
    let lo = frontier.min_peak();
    let bp = memsim::predict_memory(&memsim::Method::Backprop, &costs).max(lo + 2);
    (lo + bp) / 2
}

/// PlannedEngine vs Backprop across threads {1,4} × replicas {1,2}:
/// loss within 1e-5, gradients within the repo's 5e-3 cross-engine
/// norm; the engine's own results are 1e-5-stable across thread counts
/// and bit-stable at fixed counts.
#[test]
fn planned_engine_grid_threads_and_replicas() {
    let _pin = pin_lock();
    let net = cnn2d(10, 3, 5);
    let mut rng = Rng::new(11);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    // Budget from the *largest* shape the grid executes (the batch-4
    // single-replica step): mid(batch-4) also fits the batch-2 shard
    // plans, whose minimum peaks are strictly smaller.
    let budget = mid_budget(&net, &[4, 16, 16, 2]);
    let engine = PlannedEngine::with_budget(Some(budget));
    engine.prepare(&net, &[4, 16, 16, 2]).unwrap();
    let reference = Backprop.compute(&net, &x, &MeanLoss).unwrap();
    let mut across_threads: Vec<Vec<Tensor>> = Vec::new();
    for threads in [1usize, 4] {
        for replicas in [1usize, 2] {
            let xs = split_batch(&x, replicas).unwrap();
            let shards: Vec<Shard<'_>> = xs
                .iter()
                .map(|x| Shard {
                    x,
                    loss: &MeanLoss,
                })
                .collect();
            let group = ReplicaGroup::new(replicas).unwrap();
            let got = pool::with_threads(threads, || {
                group
                    .compute(&net, &engine, &shards, ReduceOp::Mean)
                    .unwrap()
            });
            assert!(
                (got.loss - reference.loss).abs() <= 1e-5 * reference.loss.abs().max(1.0),
                "t={threads} r={replicas}: loss {} vs {}",
                got.loss,
                reference.loss
            );
            for (li, (a, b)) in reference.grads.iter().zip(&got.grads).enumerate() {
                assert_eq!(a.len(), b.len(), "t={threads} r={replicas} layer {li}");
                for (ga, gb) in a.iter().zip(b) {
                    let err = rel_err(gb, ga);
                    assert!(
                        err <= 5e-3,
                        "t={threads} r={replicas} layer {li}: rel err {err}"
                    );
                }
            }
            if replicas == 1 {
                across_threads.push(got.grads.into_iter().flatten().collect());
            }
        }
    }
    // The engine's own gradients across thread counts: ≤ 1e-5 (the only
    // cross-count reassociation is the worker-ordered vjp_params merge).
    let (g1, g4) = (&across_threads[0], &across_threads[1]);
    for (a, b) in g1.iter().zip(g4) {
        let err = rel_err(b, a);
        assert!(err <= 1e-5, "planned 4-thread vs 1-thread rel err {err}");
    }
}

/// With an unbounded budget the compiled plan checkpoints every
/// cotangent, which makes the engine bit-identical to Backprop — the
/// strongest form of the equivalence contract, and deterministic
/// run-to-run.
#[test]
fn planned_unbounded_bit_identical_to_backprop_under_replicas() {
    let _pin = pin_lock();
    let net = cnn2d(12, 2, 4);
    let mut rng = Rng::new(13);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let engine = PlannedEngine::with_budget(None);
    pool::with_threads(2, || {
        let xs = split_batch(&x, 2).unwrap();
        let shards: Vec<Shard<'_>> = xs
            .iter()
            .map(|x| Shard {
                x,
                loss: &MeanLoss,
            })
            .collect();
        let group = ReplicaGroup::new(2).unwrap();
        let planned = group.compute(&net, &engine, &shards, ReduceOp::Mean).unwrap();
        let bp = group
            .compute(&net, &Backprop, &shards, ReduceOp::Mean)
            .unwrap();
        assert_eq!(planned.loss.to_bits(), bp.loss.to_bits());
        for (la, lb) in planned.grads.iter().zip(&bp.grads) {
            for (ga, gb) in la.iter().zip(lb) {
                assert_eq!(ga.data(), gb.data(), "unbounded plan must equal backprop");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// 4. Measured budget respect + loss-curve match (the --budget contract)
// ---------------------------------------------------------------------------

/// A plan compiled for a budget midway between the pure-Moonwalk and
/// Backprop peaks executes with a *measured* tracker peak at or under
/// the budget (grad-free accounting, the paper's metric), on the deep
/// resolution-preserving net where the gap is widest.
#[test]
fn measured_peak_respects_mid_budget() {
    let _pin = pin_lock();
    let net = cnn1d(20, 6, 12, 128);
    let in_shape = [2usize, 128, 3];
    let mut rng = Rng::new(21);
    let x = Tensor::randn(&in_shape, 1.0, &mut rng);
    let budget = mid_budget(&net, &in_shape);
    let engine = PlannedEngine::with_budget(Some(budget));
    let compiled = engine.prepare(&net, &in_shape).unwrap();
    assert!(compiled.conservative_peak <= budget);
    assert!(compiled.planned_peak <= compiled.conservative_peak);
    // The mid budget must actually force a mixed (non-all-checkpoint)
    // plan, or the test is vacuous.
    assert!(
        compiled
            .decisions
            .iter()
            .any(|d| matches!(d.strategy, Strategy::Vijp | Strategy::Fragment { .. })),
        "mid budget should force vijp/fragment strategies: {}",
        compiled.mix()
    );
    pool::with_threads(1, || {
        // Unmeasured warm-up populates the scratch arena, as every
        // memory-profiled path in this repo does.
        engine
            .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
            .unwrap();
        let (res, prof) = tracker::measure(|| {
            engine.compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
        });
        res.unwrap();
        assert!(
            prof.peak_extra_bytes <= budget,
            "measured peak {} exceeds budget {budget} (planned {}, conservative {})",
            prof.peak_extra_bytes,
            compiled.planned_peak,
            compiled.conservative_peak
        );
    });
}

/// Training with the mid-budget PlannedEngine tracks Backprop's loss
/// curve: identical at step 1 (identical parameters ⇒ identical forward,
/// ≤ 1e-5), and within the cross-engine gradient tolerance as the
/// trajectories evolve; the trainer logs `planned_peak` beside the
/// measured peak.
#[test]
fn planned_training_matches_backprop_curve_and_logs_plan() {
    use moonwalk::coordinator::{Optimizer, OptimizerKind, SyntheticSpec, TextureDataset, Trainer};
    use moonwalk::util::json::Json;
    let _pin = pin_lock();
    let data = TextureDataset::generate(
        SyntheticSpec {
            hw: 16,
            cin: 2,
            classes: 3,
            noise: 0.15,
            seed: 30,
        },
        40,
    );
    let (train, test) = data.split(0.2);
    let steps = 6usize;
    let run = |engine: &dyn GradEngine, metrics: Option<&std::path::Path>| {
        let mut net = cnn2d(31, 2, 5);
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, true);
        let mut t = Trainer::new(&mut net, engine, opt);
        t.log_every = 1;
        let mut rng = Rng::new(32);
        t.train(&train, &test, 4, steps, &mut rng, metrics).unwrap()
    };
    let budget = mid_budget(&cnn2d(31, 2, 5), &[4, 16, 16, 2]);
    let planned = PlannedEngine::with_budget(Some(budget));
    planned.prepare(&cnn2d(31, 2, 5), &[4, 16, 16, 2]).unwrap();
    let dir = std::env::temp_dir().join("moonwalk_planner_trainer_test");
    let path = dir.join("metrics.jsonl");
    let rep_planned = run(&planned, Some(&path));
    let rep_bp = run(&Backprop, None);
    assert_eq!(rep_planned.loss_curve.len(), rep_bp.loss_curve.len());
    let first_rel = (rep_planned.loss_curve[0] - rep_bp.loss_curve[0]).abs()
        / rep_bp.loss_curve[0].abs().max(1.0);
    assert!(first_rel <= 1e-5, "step-1 loss must match: rel {first_rel}");
    for (i, (a, b)) in rep_planned
        .loss_curve
        .iter()
        .zip(&rep_bp.loss_curve)
        .enumerate()
    {
        let rel = (a - b).abs() / b.abs().max(1.0);
        assert!(rel <= 5e-3, "step {i}: loss curves diverged ({a} vs {b})");
    }
    assert_eq!(rep_planned.planned_peak_bytes, planned.planned_peak_bytes());
    assert!(rep_bp.planned_peak_bytes.is_none());
    let text = std::fs::read_to_string(&path).unwrap();
    let first = Json::parse(text.lines().next().unwrap()).unwrap();
    assert!(first.req_usize("planned_peak").unwrap() > 0);
    assert!(first.req_usize("measured_peak").unwrap() > 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance-criterion frontier claim, asserted from the test
/// suite as well as the bench: on the fragmental net there is at least
/// one budget point where the mixed per-layer plan beats the best
/// single whole-network engine on predicted peak bytes at
/// equal-or-better predicted time.
#[test]
fn mixed_plan_beats_single_engine_at_some_budget() {
    // Depth 8 so BackpropCkpt's √L-scaled memory does not fit at the
    // tight end of the sweep (where the mixed plan's fragment-block
    // search wins against the 5×fwd Moonwalk family).
    let net = cnn1d(40, 8, 8, 128);
    let in_shape = [2usize, 128, 3];
    let probes = plan::probe_network(&net, &in_shape, plan::DEFAULT_FRAG_BLOCKS).unwrap();
    let costs: Vec<memsim::LayerCost> = probes.iter().map(|p| p.cost.clone()).collect();
    let input_elems: usize = in_shape.iter().product();
    let fwd: f64 = costs.iter().map(|c| c.flops).sum();
    let frontier = plan::build_frontier(&probes);
    let lo = frontier.min_peak();
    let hi = memsim::predict_memory(&memsim::Method::Backprop, &costs).max(lo + 2);
    let mut found = false;
    for i in 0..16 {
        let budget = lo + (hi - lo) * i / 16;
        let Ok(compiled) = frontier.select(&probes, Some(budget)) else {
            continue;
        };
        let Some((_, single_mem, single_t)) = memsim::plan(&costs, budget, true, input_elems)
        else {
            continue;
        };
        if compiled.planned_peak < single_mem && compiled.time_units / fwd <= single_t / fwd {
            found = true;
            break;
        }
    }
    assert!(found, "no budget point where the mixed plan wins");
}
