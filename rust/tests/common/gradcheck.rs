//! Finite-difference gradient checking against the public `Layer` API.
//!
//! The in-crate unit tests validate each layer's operators against its
//! own `jvp`; this harness is deliberately more paranoid — every check
//! here compares an analytic operator against **central differences of
//! `forward` alone**, so a layer whose `jvp` and `vjp` share a bug still
//! fails. All comparisons are relative: `|analytic − fd| / max(|fd|, 1)`
//! must stay below the caller's tolerance (the reversible-family
//! acceptance bar is 1e-3; see `tests/reversible.rs`).

use moonwalk::nn::{Layer, ResidualKind};
use moonwalk::tensor::{ops, Tensor};
use moonwalk::util::Rng;

/// Default central-difference step. f32 forward passes lose ~1e-3 of a
/// unit-scale signal to cancellation below this; above it the O(ε²)
/// truncation term dominates.
pub const FD_EPS: f32 = 1e-2;

/// Directional derivative of `forward` at `x` along `u`, by central
/// differences: `(f(x + εu) − f(x − εu)) / 2ε`.
pub fn fd_directional(layer: &dyn Layer, x: &Tensor, u: &Tensor, eps: f32) -> Tensor {
    let xp = ops::add(x, &ops::scale(u, eps));
    let xm = ops::sub(x, &ops::scale(u, eps));
    ops::scale(&ops::sub(&layer.forward(&xp), &layer.forward(&xm)), 0.5 / eps)
}

fn rel_gap(analytic: f32, fd: f32) -> f32 {
    (analytic - fd).abs() / fd.abs().max(1.0)
}

/// Check `vjp_input` against finite differences: for random directions
/// `u` and cotangents `h'`, the adjoint identity
/// `⟨vjp_input(h'), u⟩ = ⟨h', ∂f/∂x · u⟩` must hold, with the right-hand
/// Jacobian-vector product measured numerically from `forward`.
pub fn check_vjp_input_fd(layer: &dyn Layer, x: &Tensor, seed: u64, tol: f32) {
    let mut rng = Rng::new(seed);
    let (y, res) = layer.forward_res(x, ResidualKind::Full);
    for trial in 0..3 {
        let u = Tensor::randn(x.shape(), 1.0, &mut rng);
        let hprime = Tensor::randn(y.shape(), 1.0, &mut rng);
        let fd = ops::dot(&hprime, &fd_directional(layer, x, &u, FD_EPS));
        let an = ops::dot(&layer.vjp_input(&res, &hprime), &u);
        assert!(
            rel_gap(an, fd) < tol,
            "{}: vjp_input vs central differences (trial {trial}): \
             analytic {an} vs fd {fd}",
            layer.name()
        );
    }
}

/// Check `vjp_params` against finite differences, perturbing the real
/// parameter storage through `params_mut` (and restoring it exactly):
/// for random parameter directions `dθ` and cotangents `h'`,
/// `Σᵢ ⟨vjp_params(x, h')ᵢ, dθᵢ⟩ = ⟨h', (f(θ+εdθ)(x) − f(θ−εdθ)(x))/2ε⟩`.
/// Layers without parameters pass trivially.
pub fn check_vjp_params_fd(layer: &mut dyn Layer, x: &Tensor, seed: u64, tol: f32) {
    if layer.n_params() == 0 {
        return;
    }
    let mut rng = Rng::new(seed);
    let y = layer.forward(x);
    for trial in 0..3 {
        let hprime = Tensor::randn(y.shape(), 1.0, &mut rng);
        let dparams: Vec<Tensor> = layer
            .params()
            .iter()
            .map(|p| Tensor::randn(p.shape(), 1.0, &mut rng))
            .collect();
        let yp = forward_perturbed(layer, x, &dparams, FD_EPS);
        let ym = forward_perturbed(layer, x, &dparams, -FD_EPS);
        let fd = ops::dot(&hprime, &ops::scale(&ops::sub(&yp, &ym), 0.5 / FD_EPS));
        let an: f32 = layer
            .vjp_params(x, &hprime)
            .iter()
            .zip(&dparams)
            .map(|(g, d)| ops::dot(g, d))
            .sum();
        assert!(
            rel_gap(an, fd) < tol,
            "{}: vjp_params vs central differences (trial {trial}): \
             analytic {an} vs fd {fd}",
            layer.name()
        );
    }
}

/// `f(θ + εdθ)(x)` evaluated by shifting the live parameters and shifting
/// them back afterwards. Add-then-subtract of the same f32 values is not
/// bit-exact, so the original data is saved and restored verbatim.
fn forward_perturbed(layer: &mut dyn Layer, x: &Tensor, dparams: &[Tensor], eps: f32) -> Tensor {
    let saved: Vec<Vec<f32>> = layer.params().iter().map(|p| p.data().to_vec()).collect();
    for (p, d) in layer.params_mut().into_iter().zip(dparams) {
        for (pv, dv) in p.data_mut().iter_mut().zip(d.data()) {
            *pv += eps * dv;
        }
    }
    let y = layer.forward(x);
    for (p, orig) in layer.params_mut().into_iter().zip(&saved) {
        p.data_mut().copy_from_slice(orig);
    }
    y
}

/// THE Moonwalk property, via the public API: on a submersive layer,
/// `vijp` must be a right inverse of `vjp_input` on the row space —
/// `vijp(vjp_input(h')) == h'` for any output cotangent `h'`.
pub fn check_vijp_roundtrip(layer: &dyn Layer, x: &Tensor, seed: u64, tol: f32) {
    let mut rng = Rng::new(seed);
    let (y, res) = layer.forward_res(x, ResidualKind::Minimal);
    for trial in 0..3 {
        let hprime = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = layer.vjp_input(&res, &hprime);
        let recovered = layer
            .vijp(&res, &h)
            .unwrap_or_else(|e| panic!("{}: submersive layer's vijp failed: {e}", layer.name()));
        let err = moonwalk::tensor::rel_err(&recovered, &hprime);
        assert!(
            err < tol,
            "{}: vijp round-trip rel err {err} ≥ {tol} (trial {trial})",
            layer.name()
        );
    }
}

/// Full gradcheck battery for one layer on one input: `vjp_input` and
/// `vjp_params` against central differences, plus — iff the layer
/// reports itself submersive — the `vijp ∘ vjp_input` round-trip. The
/// submersivity flag itself is cross-checked: a non-submersive layer's
/// `vijp` must return an error, not wrong numbers.
pub fn gradcheck_layer(layer: &mut dyn Layer, x: &Tensor, seed: u64, tol: f32) {
    check_vjp_input_fd(layer, x, seed, tol);
    check_vjp_params_fd(layer, x, seed ^ 0x9e3779b9, tol);
    let (y, res) = layer.forward_res(x, ResidualKind::Minimal);
    if layer.submersivity().is_submersive() {
        check_vijp_roundtrip(layer, x, seed ^ 0xdeadbeef, tol);
    } else {
        let mut rng = Rng::new(seed);
        let h = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h_in = layer.vjp_input(&res, &h);
        assert!(
            layer.vijp(&res, &h_in).is_err(),
            "{}: non-submersive layer's vijp must err",
            layer.name()
        );
    }
}
