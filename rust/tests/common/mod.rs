//! Shared helpers for integration tests. Files under `tests/common/` are
//! not compiled as test binaries; suites pull them in with `mod common;`.
#![allow(dead_code)]

pub mod gradcheck;
