//! Parallel tensor runtime properties (§Perf iteration 5):
//!
//! 1. Every parallel kernel (GEMM variants, batch-parallel conv ops, the
//!    vijp elimination in both regimes, Dense, whole gradient engines)
//!    matches the single-threaded reference within 1e-5 across a grid of
//!    shapes — including the `s + p < k` wavefront convolution.
//! 2. Determinism: with a fixed `--threads`, two runs from the same seed
//!    are **bit-identical**.
//!
//! The worker count is process-global, so these tests serialize through a
//! local mutex and restore the previous setting on exit.

use std::sync::Mutex;

use moonwalk::autodiff::{Backprop, GradEngine, Moonwalk, MoonwalkOpts};
use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
use moonwalk::nn::{Conv1d, Conv2d, Dense, Layer, MeanLoss, ResidualKind};
use moonwalk::runtime::pool;
use moonwalk::tensor::{assert_close, ops, rel_err, Tensor};
use moonwalk::util::Rng;

static LOCK: Mutex<()> = Mutex::new(());

/// Restores the pool's thread count on drop — panic-safe, so a failing
/// assertion inside `with_threads` can't leak a pinned count into the
/// rest of the file (the mutex deliberately ignores poisoning).
struct ThreadGuard(usize);
impl Drop for ThreadGuard {
    fn drop(&mut self) {
        pool::set_threads(self.0);
    }
}

/// Run `f` with the pool pinned to `t` workers, restoring the previous
/// setting afterwards even on panic (tests in this file serialize via
/// `LOCK`).
fn with_threads<T>(t: usize, f: impl FnOnce() -> T) -> T {
    let _guard = ThreadGuard(pool::threads());
    pool::set_threads(t);
    f()
}

/// Forces the Parallel GEMM algorithm until dropped (panic-safe).
struct ForcedParallelGemm;
impl ForcedParallelGemm {
    fn engage() -> ForcedParallelGemm {
        ops::set_gemm_override("parallel").unwrap();
        ForcedParallelGemm
    }
}
impl Drop for ForcedParallelGemm {
    fn drop(&mut self) {
        let _ = ops::set_gemm_override("auto");
    }
}

fn lock() -> std::sync::MutexGuard<'static, ()> {
    match LOCK.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

#[test]
fn gemm_grid_parallel_matches_serial() {
    let _g = lock();
    // Force the Parallel algorithm so even sub-threshold shapes exercise
    // the fan-out path (auto would keep small grids on Blocked).
    let _algo = ForcedParallelGemm::engage();
    let mut rng = Rng::new(100);
    for &(m, k, n) in &[
        (1usize, 8usize, 8usize),
        (17, 9, 5),
        (64, 32, 32),
        (130, 70, 33),
        (256, 16, 64),
    ] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let at = ops::transpose(&a);
        let bt = ops::transpose(&b);
        let (c1, c1_tn, c1_nt) = with_threads(1, || {
            (ops::matmul(&a, &b), ops::matmul_tn(&at, &b), ops::matmul_nt(&a, &bt))
        });
        for t in [2usize, 4] {
            let (ct, ct_tn, ct_nt) = with_threads(t, || {
                (ops::matmul(&a, &b), ops::matmul_tn(&at, &b), ops::matmul_nt(&a, &bt))
            });
            assert!(rel_err(&ct, &c1) <= 1e-5, "matmul {m}x{k}x{n} t={t}");
            assert!(rel_err(&ct_tn, &c1_tn) <= 1e-5, "matmul_tn {m}x{k}x{n} t={t}");
            assert!(rel_err(&ct_nt, &c1_nt) <= 1e-5, "matmul_nt {m}x{k}x{n} t={t}");
        }
    }
}

/// All four conv2d operators across fast-path, wavefront (`s+p<k`) and
/// channel-reducing geometries.
#[test]
fn conv2d_ops_parallel_match_serial() {
    let _g = lock();
    // (k, s, p, cin, cout, hw)
    for &(k, s, p, cin, cout, hw) in &[
        (3usize, 2usize, 1usize, 4usize, 4usize, 9usize), // fast path
        (5, 3, 2, 4, 4, 13),                              // s+p>=k boundary
        (5, 3, 1, 3, 3, 13),                              // wavefront: s+p<k
        (3, 2, 1, 6, 3, 9),                               // channel-reducing
    ] {
        let mut rng = Rng::new(7 + k as u64);
        let conv = Conv2d::new_submersive(k, cin, cout, s, p, false, &mut rng);
        let x = Tensor::randn(&[5, hw, hw, cin], 1.0, &mut rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &g);

        let (y1, vi1, vw1, vj1) = with_threads(1, || {
            (
                conv.forward(&x),
                conv.vjp_input(&res, &g),
                conv.vjp_params(&x, &g),
                conv.vijp(&res, &h).unwrap(),
            )
        });
        for t in [2usize, 4] {
            let (yt, vit, vwt, vjt) = with_threads(t, || {
                (
                    conv.forward(&x),
                    conv.vjp_input(&res, &g),
                    conv.vjp_params(&x, &g),
                    conv.vijp(&res, &h).unwrap(),
                )
            });
            let tag = format!("conv2d k{k}s{s}p{p} {cin}->{cout} t={t}");
            assert_close(&yt, &y1, 1e-5, &format!("{tag} fwd"));
            assert_close(&vit, &vi1, 1e-5, &format!("{tag} vjp_input"));
            for (a, b) in vwt.iter().zip(&vw1) {
                assert_close(a, b, 1e-5, &format!("{tag} vjp_params"));
            }
            assert_close(&vjt, &vj1, 1e-5, &format!("{tag} vijp"));
        }
    }
}

#[test]
fn conv1d_ops_parallel_match_serial() {
    let _g = lock();
    for &(k, s, p, cin, cout, l) in &[
        (3usize, 2usize, 1usize, 4usize, 4usize, 11usize),
        (5, 3, 1, 3, 3, 16), // wavefront geometry in 1-D
    ] {
        let mut rng = Rng::new(21 + k as u64);
        let conv = Conv1d::new_submersive(k, cin, cout, s, p, &mut rng);
        let x = Tensor::randn(&[6, l, cin], 1.0, &mut rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &g);

        let (y1, vi1, vw1, vj1) = with_threads(1, || {
            (
                conv.forward(&x),
                conv.vjp_input(&res, &g),
                conv.vjp_params(&x, &g),
                conv.vijp(&res, &h).unwrap(),
            )
        });
        for t in [3usize, 4] {
            let (yt, vit, vwt, vjt) = with_threads(t, || {
                (
                    conv.forward(&x),
                    conv.vjp_input(&res, &g),
                    conv.vjp_params(&x, &g),
                    conv.vijp(&res, &h).unwrap(),
                )
            });
            let tag = format!("conv1d k{k}s{s}p{p} t={t}");
            assert_close(&yt, &y1, 1e-5, &format!("{tag} fwd"));
            assert_close(&vit, &vi1, 1e-5, &format!("{tag} vjp_input"));
            for (a, b) in vwt.iter().zip(&vw1) {
                assert_close(a, b, 1e-5, &format!("{tag} vjp_params"));
            }
            assert_close(&vjt, &vj1, 1e-5, &format!("{tag} vijp"));
        }
    }
}

#[test]
fn dense_parallel_matches_serial() {
    let _g = lock();
    let _algo = ForcedParallelGemm::engage();
    let mut rng = Rng::new(33);
    let dense = Dense::new(48, 10, true, &mut rng);
    let x = Tensor::randn(&[64, 48], 1.0, &mut rng);
    let (y, res) = dense.forward_res(&x, ResidualKind::Minimal);
    let g = Tensor::randn(y.shape(), 1.0, &mut rng);

    let (y1, vi1, vw1) = with_threads(1, || {
        (
            dense.forward(&x),
            dense.vjp_input(&res, &g),
            dense.vjp_params(&x, &g),
        )
    });
    let (y4, vi4, vw4) = with_threads(4, || {
        (
            dense.forward(&x),
            dense.vjp_input(&res, &g),
            dense.vjp_params(&x, &g),
        )
    });
    assert_close(&y4, &y1, 1e-5, "dense fwd");
    assert_close(&vi4, &vi1, 1e-5, "dense vjp_input");
    for (a, b) in vw4.iter().zip(&vw1) {
        assert_close(a, b, 1e-5, "dense vjp_params");
    }
}

/// End-to-end: the Moonwalk engine on 4 threads reproduces its own
/// 1-thread gradients to 1e-5 and Backprop's to engine tolerance.
#[test]
fn moonwalk_engine_parallel_matches_serial() {
    let _g = lock();
    let mut rng = Rng::new(55);
    let spec = SubmersiveCnn2dSpec {
        input_hw: 16,
        depth: 3,
        channels: 4,
        cin: 2,
        classes: 3,
        ..Default::default()
    };
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let mw = Moonwalk::new(MoonwalkOpts::default());
    let r1 = with_threads(1, || mw.compute(&net, &x, &MeanLoss).unwrap());
    let r4 = with_threads(4, || mw.compute(&net, &x, &MeanLoss).unwrap());
    assert!((r1.loss - r4.loss).abs() <= 1e-6);
    for (a, b) in r1.grads.iter().flatten().zip(r4.grads.iter().flatten()) {
        assert_close(b, a, 1e-5, "moonwalk grads 4 vs 1 thread");
    }
    let bp = with_threads(4, || Backprop.compute(&net, &x, &MeanLoss).unwrap());
    for (a, b) in bp.grads.iter().flatten().zip(r4.grads.iter().flatten()) {
        assert_close(b, a, 5e-3, "moonwalk(4t) vs backprop(4t)");
    }
}

/// Same seed + fixed thread count ⇒ bit-identical outputs across runs
/// (the determinism contract of the deterministic chunk partitioning and
/// worker-ordered reductions).
#[test]
fn fixed_threads_runs_are_bit_identical() {
    let _g = lock();
    let run = || {
        let mut rng = Rng::new(77);
        let conv = Conv2d::new_submersive(3, 4, 4, 2, 1, false, &mut rng);
        let x = Tensor::randn(&[5, 9, 9, 4], 1.0, &mut rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &g);
        let hp = conv.vijp(&res, &h).unwrap();
        let dw = conv.vjp_params(&x, &g);
        (y, h, hp, dw)
    };
    let (y_a, h_a, hp_a, dw_a) = with_threads(3, run);
    let (y_b, h_b, hp_b, dw_b) = with_threads(3, run);
    assert_eq!(y_a.data(), y_b.data(), "forward bit-identical");
    assert_eq!(h_a.data(), h_b.data(), "vjp_input bit-identical");
    assert_eq!(hp_a.data(), hp_b.data(), "vijp bit-identical");
    for (a, b) in dw_a.iter().zip(&dw_b) {
        assert_eq!(a.data(), b.data(), "vjp_params bit-identical");
    }
}
