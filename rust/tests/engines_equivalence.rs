//! Cross-engine gradient equivalence — the repo's central correctness
//! property: every exact engine must reproduce Backprop's gradients on
//! every architecture family it applies to (paper: Moonwalk computes
//! *true* gradients, unlike projection methods).

use std::sync::Mutex;

use moonwalk::autodiff::{
    engine_by_name, Backprop, ForwardMode, GradEngine, Moonwalk, MoonwalkOpts, PureMoonwalk,
    RevBackprop, EXACT_ENGINES,
};
use moonwalk::model::{
    build_cnn1d_fragmental, build_cnn2d, build_invertible_cnn2d, build_mlp,
    FragmentalCnn1dSpec, Network, SubmersiveCnn2dSpec,
};
use moonwalk::nn::{Loss, MeanLoss, SoftmaxCrossEntropy};
use moonwalk::runtime::pool;
use moonwalk::tensor::{rel_err, Tensor};
use moonwalk::util::Rng;

/// Serializes the tests that pin the (process-global) pool thread count.
static THREAD_PIN: Mutex<()> = Mutex::new(());

fn pin_lock() -> std::sync::MutexGuard<'static, ()> {
    match THREAD_PIN.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn assert_engines_match(
    net: &Network,
    x: &Tensor,
    loss: &dyn Loss,
    engines: &[&dyn GradEngine],
    tol: f32,
) {
    let reference = Backprop.compute(net, x, loss).unwrap();
    for engine in engines {
        let got = engine
            .compute(net, x, loss)
            .unwrap_or_else(|e| panic!("{} failed: {e}", engine.name()));
        assert!(
            (got.loss - reference.loss).abs() <= 1e-5 * reference.loss.abs().max(1.0),
            "{}: loss {} vs {}",
            engine.name(),
            got.loss,
            reference.loss
        );
        for (li, (a, b)) in reference.grads.iter().zip(&got.grads).enumerate() {
            assert_eq!(a.len(), b.len(), "{}: arity at layer {li}", engine.name());
            for (pi, (ga, gb)) in a.iter().zip(b).enumerate() {
                let err = rel_err(gb, ga);
                assert!(
                    err <= tol,
                    "{} layer {li} param {pi}: rel err {err} > {tol}",
                    engine.name()
                );
            }
        }
    }
}

#[test]
fn all_exact_engines_on_submersive_cnn2d() {
    let mut rng = Rng::new(0);
    let spec = SubmersiveCnn2dSpec {
        input_hw: 16,
        depth: 3,
        channels: 5,
        cin: 2,
        classes: 3,
        ..Default::default()
    };
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[2, 16, 16, 2], 1.0, &mut rng);
    let engines: Vec<Box<dyn GradEngine>> = EXACT_ENGINES
        .iter()
        .map(|n| engine_by_name(n, 4, 2, 0).unwrap())
        .collect();
    let refs: Vec<&dyn GradEngine> = engines.iter().map(|e| e.as_ref()).collect();
    assert_engines_match(&net, &x, &MeanLoss, &refs, 5e-3);
}

#[test]
fn all_exact_engines_with_xent_loss() {
    let mut rng = Rng::new(1);
    let spec = SubmersiveCnn2dSpec {
        input_hw: 16,
        depth: 2,
        channels: 4,
        cin: 3,
        classes: 4,
        ..Default::default()
    };
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[3, 16, 16, 3], 1.0, &mut rng);
    let loss = SoftmaxCrossEntropy::new(vec![0, 3, 1]);
    let engines: Vec<Box<dyn GradEngine>> = EXACT_ENGINES
        .iter()
        .map(|n| engine_by_name(n, 8, 0, 0).unwrap())
        .collect();
    let refs: Vec<&dyn GradEngine> = engines.iter().map(|e| e.as_ref()).collect();
    assert_engines_match(&net, &x, &loss, &refs, 5e-3);
}

#[test]
fn fragmental_on_1d_cnn_all_blocks() {
    let mut rng = Rng::new(2);
    let spec = FragmentalCnn1dSpec {
        input_len: 64,
        channels: 8,
        depth: 3,
        classes: 3,
        ..Default::default()
    };
    let net = build_cnn1d_fragmental(&spec, &mut rng);
    let x = Tensor::randn(&[2, 64, 3], 1.0, &mut rng);
    for block in [4usize, 8, 16] {
        let engine = Moonwalk::new(MoonwalkOpts {
            fragment_block: Some(block),
            ..Default::default()
        });
        // The in-block recurrence amplifies the f32 rounding already
        // present in the Phase-II cotangents by a per-step factor set by
        // the off-pivot/pivot weight ratio, so tolerance grows with
        // block size (EXPERIMENTS.md §Numerics; the effect exists in the
        // paper's f32 GPU implementation too but is mild at their 256
        // channels where He-init taps are ~1/16 the pivot).
        let tol = 5e-3 * (block as f32 / 4.0) * (block as f32 / 4.0);
        assert_engines_match(&net, &x, &MeanLoss, &[&engine], tol);
    }
}

#[test]
fn moonwalk_without_blocks_checkpoints_1d_cnn() {
    // Without fragment_block the engine must fall back to full cotangent
    // checkpoints and still be exact.
    let mut rng = Rng::new(3);
    let spec = FragmentalCnn1dSpec {
        input_len: 32,
        channels: 6,
        depth: 2,
        ..Default::default()
    };
    let net = build_cnn1d_fragmental(&spec, &mut rng);
    let x = Tensor::randn(&[1, 32, 3], 1.0, &mut rng);
    let engine = Moonwalk::new(MoonwalkOpts::default());
    assert_engines_match(&net, &x, &MeanLoss, &[&engine], 5e-3);
}

#[test]
fn revbackprop_and_all_moonwalks_on_invertible_net() {
    let mut rng = Rng::new(4);
    let net = build_invertible_cnn2d(5, 4, 0.2, &mut rng);
    let x = Tensor::randn(&[2, 6, 6, 5], 1.0, &mut rng);
    let mw = Moonwalk::new(MoonwalkOpts::default());
    let pm = PureMoonwalk;
    assert_engines_match(&net, &x, &MeanLoss, &[&RevBackprop, &mw, &pm], 1e-2);
}

#[test]
fn forward_mode_and_pure_moonwalk_on_micro_mlp() {
    let mut rng = Rng::new(5);
    let net = build_mlp(&[5, 4, 3], 0.15, &mut rng);
    let x = Tensor::randn(&[2, 5], 1.0, &mut rng);
    let mw = Moonwalk::new(MoonwalkOpts::default());
    assert_engines_match(&net, &x, &MeanLoss, &[&ForwardMode, &PureMoonwalk, &mw], 1e-2);
}

#[test]
fn deep_network_stability() {
    // Moonwalk's vijp chain must stay numerically stable across many
    // layers (the triangular solves could amplify error).
    let mut rng = Rng::new(6);
    let spec = SubmersiveCnn2dSpec {
        input_hw: 64,
        depth: 5,
        channels: 4,
        cin: 2,
        ..Default::default()
    };
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[1, 64, 64, 2], 1.0, &mut rng);
    let mw = Moonwalk::new(MoonwalkOpts::default());
    assert_engines_match(&net, &x, &MeanLoss, &[&mw], 1e-2);
}

#[test]
fn combined_checkpoint_and_fragmental() {
    // The two refinements compose: activation checkpointing in Phase
    // I/II together with fragmental capture at non-submersive layers.
    let mut rng = Rng::new(7);
    let spec = FragmentalCnn1dSpec {
        input_len: 64,
        channels: 8,
        depth: 4,
        ..Default::default()
    };
    let net = build_cnn1d_fragmental(&spec, &mut rng);
    let x = Tensor::randn(&[2, 64, 3], 1.0, &mut rng);
    let engine = Moonwalk::new(MoonwalkOpts {
        fragment_block: Some(8),
        checkpoint_segments: Some(2),
        ..Default::default()
    });
    assert_engines_match(&net, &x, &MeanLoss, &[&engine], 1e-2);
}

#[test]
fn mixed_pool_mid_network() {
    // Pooling mid-network (not just as the head) keeps the vijp chain
    // intact — argmax gather is a valid right-inverse anywhere.
    use moonwalk::nn::{Conv2d, LayerBox, LeakyRelu, MaxPool2d};
    let mut rng = Rng::new(8);
    let layers: Vec<LayerBox> = vec![
        Box::new(Conv2d::new_submersive(3, 4, 4, 2, 1, false, &mut rng)),
        Box::new(LeakyRelu::new(0.1)),
        Box::new(MaxPool2d::new(2)),
        Box::new(Conv2d::new_submersive(3, 4, 4, 2, 1, true, &mut rng)),
        Box::new(LeakyRelu::new(0.2)),
    ];
    let net = Network::new(layers);
    assert!(net.is_submersive());
    let x = Tensor::randn(&[2, 33, 33, 4], 1.0, &mut rng);
    let mw = Moonwalk::new(MoonwalkOpts::default());
    assert_engines_match(&net, &x, &MeanLoss, &[&mw], 5e-3);
}

/// The full `EXACT_ENGINES` grid under the persistent pool: at
/// `threads ∈ {1, 4}` every exact engine reproduces Backprop's gradients
/// on the 2-D submersive CNN, and each engine's 4-thread gradients match
/// its own 1-thread gradients to 1e-5 (the only cross-count
/// reassociation is the worker-ordered `vjp_params` merge).
#[test]
fn exact_engines_grid_under_threads_1_and_4() {
    let _pin = pin_lock();
    let mut rng = Rng::new(20);
    let spec = SubmersiveCnn2dSpec {
        input_hw: 16,
        depth: 3,
        channels: 5,
        cin: 2,
        classes: 3,
        ..Default::default()
    };
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[2, 16, 16, 2], 1.0, &mut rng);
    let engines: Vec<Box<dyn GradEngine>> = EXACT_ENGINES
        .iter()
        .map(|n| engine_by_name(n, 4, 2, 0).unwrap())
        .collect();
    for t in [1usize, 4] {
        pool::with_threads(t, || {
            let refs: Vec<&dyn GradEngine> = engines.iter().map(|e| e.as_ref()).collect();
            assert_engines_match(&net, &x, &MeanLoss, &refs, 5e-3);
        });
    }
    for (name, engine) in EXACT_ENGINES.iter().zip(&engines) {
        let r1 = pool::with_threads(1, || engine.compute(&net, &x, &MeanLoss).unwrap());
        let r4 = pool::with_threads(4, || engine.compute(&net, &x, &MeanLoss).unwrap());
        assert!(
            (r1.loss - r4.loss).abs() <= 1e-6 * r1.loss.abs().max(1.0),
            "{name}: loss diverged across thread counts"
        );
        for (li, (a, b)) in r1.grads.iter().zip(&r4.grads).enumerate() {
            for (pi, (ga, gb)) in a.iter().zip(b).enumerate() {
                let err = rel_err(gb, ga);
                assert!(
                    err <= 1e-5,
                    "{name} layer {li} param {pi}: 4-thread vs 1-thread rel err {err}"
                );
            }
        }
    }
}

/// Moonwalk with fragmental checkpointing on the 1-D CNN, under the
/// persistent pool at both thread counts: gradients match Backprop, and
/// the 4-thread run matches the 1-thread run to 1e-5 (the fragment
/// reconstruction itself is bit-identical — see
/// `prop_fragment_reconstruct_parallel_bit_identical` — the residual
/// reassociation comes from the worker-ordered `vjp_params` merge).
#[test]
fn fragmental_moonwalk_grid_under_threads_1_and_4() {
    let _pin = pin_lock();
    let mut rng = Rng::new(21);
    let spec = FragmentalCnn1dSpec {
        input_len: 64,
        channels: 8,
        depth: 3,
        classes: 3,
        ..Default::default()
    };
    let net = build_cnn1d_fragmental(&spec, &mut rng);
    let x = Tensor::randn(&[2, 64, 3], 1.0, &mut rng);
    let engine = Moonwalk::new(MoonwalkOpts {
        fragment_block: Some(8),
        ..Default::default()
    });
    for t in [1usize, 4] {
        pool::with_threads(t, || {
            // Tolerance per the block-8 recurrence bound documented in
            // `fragmental_on_1d_cnn_all_blocks`.
            assert_engines_match(&net, &x, &MeanLoss, &[&engine], 2e-2);
        });
    }
    let r1 = pool::with_threads(1, || engine.compute(&net, &x, &MeanLoss).unwrap());
    let r4 = pool::with_threads(4, || engine.compute(&net, &x, &MeanLoss).unwrap());
    for (ga, gb) in r1.grads.iter().flatten().zip(r4.grads.iter().flatten()) {
        let err = rel_err(gb, ga);
        assert!(
            err <= 1e-5,
            "fragmental moonwalk: 4-thread vs 1-thread rel err {err}"
        );
    }
}

#[test]
fn gradients_deterministic_across_runs() {
    // Engines are bit-deterministic (required for the AOT parity tests).
    // Bit-equality needs a *fixed* thread count across the two runs, so
    // pin it and serialize against the thread-pinning grid tests (the
    // batch-1 input exercises the spatial row-band reductions, whose
    // partitioning depends on the count).
    let _pin = pin_lock();
    let mut rng = Rng::new(9);
    let spec = SubmersiveCnn2dSpec {
        input_hw: 16,
        depth: 2,
        channels: 4,
        cin: 2,
        ..Default::default()
    };
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[1, 16, 16, 2], 1.0, &mut rng);
    let mw = Moonwalk::new(MoonwalkOpts::default());
    pool::with_threads(4, || {
        let a = mw.compute(&net, &x, &MeanLoss).unwrap();
        let b = mw.compute(&net, &x, &MeanLoss).unwrap();
        for (ga, gb) in a.grads.iter().flatten().zip(b.grads.iter().flatten()) {
            assert_eq!(ga.data(), gb.data());
        }
    });
}
