//! Property-based tests (hand-rolled harness — the offline image has no
//! proptest): randomized geometry/shape sweeps over the paper's
//! invariants, with the failing seed printed for reproduction.

use std::sync::Mutex;

use moonwalk::nn::{
    Conv1d, Conv2d, Dense, Layer, LeakyRelu, MaxPool2d, ResidualKind, Submersivity,
};
use moonwalk::runtime::pool;
use moonwalk::tensor::{rel_err, tracker, Tensor};
use moonwalk::util::Rng;

/// Serializes the tests that pin the (process-global) pool thread count;
/// the other properties are thread-count agnostic and run concurrently.
static THREAD_PIN: Mutex<()> = Mutex::new(());

fn pin_lock() -> std::sync::MutexGuard<'static, ()> {
    match THREAD_PIN.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Run `f` across `trials` random cases; panic with the failing seed.
fn for_random_cases(base_seed: u64, trials: usize, f: impl Fn(&mut Rng)) {
    for t in 0..trials {
        let seed = base_seed.wrapping_mul(1_000_003).wrapping_add(t as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property failed at seed {seed} (trial {t}): {e:?}");
        }
    }
}

/// Random submersive conv2d geometry satisfying Lemma 1.
fn random_submersive_conv2d(rng: &mut Rng) -> (Conv2d, Tensor) {
    let s = rng.int_range(2, 4); // stride 2..3
    let p = rng.int_range(0, s.min(2)); // p < s
    // k > 2p guarantees the Lemma-1 spatial bound n > s(n'-1) for every
    // input size; the upper end still produces wavefront cases (k > s+p).
    let k = rng.int_range(2 * p + 1, 2 * p + s + 1);
    let cout = rng.int_range(1, 6);
    let cin = cout + rng.int_range(0, 3);
    let conv = Conv2d::new_submersive(k, cin, cout, s, p, rng.bernoulli(0.5), rng);
    // Input large enough for a valid output and the spatial bound.
    let min_hw = k.max(s * 2 + 1) + s;
    let hw = rng.int_range(min_hw, min_hw + 8);
    let n = rng.int_range(1, 3);
    let x = Tensor::randn(&[n, hw, hw, cin], 1.0, rng);
    (conv, x)
}

/// vijp ∘ vjp = identity on the row space, for random Lemma-1 geometries
/// (paper §4.2 uniqueness claim).
#[test]
fn prop_vijp_right_inverse_conv2d() {
    for_random_cases(100, 40, |rng| {
        let (conv, x) = random_submersive_conv2d(rng);
        assert!(
            conv.submersivity().is_submersive(),
            "constructor must satisfy Lemma 1: {:?} {}",
            conv.submersivity(),
            conv.name()
        );
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let hp = Tensor::randn(y.shape(), 1.0, rng);
        let h = conv.vjp_input(&res, &hp);
        match conv.vijp(&res, &h) {
            Ok(rec) => {
                let err = rel_err(&rec, &hp);
                assert!(err < 5e-2, "{}: rel err {err}", conv.name());
            }
            Err(e) => panic!("{}: {e}", conv.name()),
        }
    });
}

/// Fragmental reconstruction is exact for random (k, B, channels, length).
#[test]
fn prop_fragment_roundtrip_conv1d() {
    for_random_cases(200, 40, |rng| {
        let k = rng.int_range(2, 5);
        let cout = rng.int_range(1, 6);
        let cin = cout + rng.int_range(0, 3);
        let mut conv = Conv1d::new_fragmental(k, cin, cout, rng);
        // The Alg.-3 recurrence is numerically stable only when the
        // off-pivot taps are contractive relative to the tap-0 diagonal
        // (EXPERIMENTS.md §Numerics). At the paper's channel counts He
        // init lands in that regime; at test-scale channels we dampen
        // explicitly and re-project.
        for (i, v) in conv.w.data_mut().iter_mut().enumerate() {
            // w layout [k, cin, cout]: tap j = i/(cin*cout), ci, co below.
            let j = i / (cin * cout);
            let r = i % (cin * cout);
            let (ci, co) = (r / cout, r % cout);
            if !(j == 0 && ci == co) {
                *v *= 0.2; // keep the pivot diagonal dominant
            }
        }
        conv.project_submersive();
        let block = k + rng.int_range(0, 13).min(12);
        let l = rng.int_range(2 * block, 5 * block);
        let x = Tensor::randn(&[rng.int_range(1, 3), l, cin], 1.0, rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let hp = Tensor::randn(y.shape(), 1.0, rng);
        let h = conv.vjp_input(&res, &hp);
        let frag = conv.fragment_capture(&hp, block).unwrap();
        let rec = conv.fragment_reconstruct(&frag, &h).unwrap();
        let err = rel_err(&rec, &hp);
        assert!(err < 5e-2, "{} B={block}: rel err {err}", conv.name());
    });
}

/// The vjp/jvp adjoint identity <vjp(h), u> = <h, jvp(u)> for every layer
/// type (randomized).
#[test]
fn prop_adjoint_identity_all_layers() {
    for_random_cases(300, 25, |rng| {
        let ch = rng.int_range(2, 5);
        let hw = rng.int_range(6, 12) & !1; // even for pooling
        let layers: Vec<Box<dyn Layer>> = vec![
            Box::new(Conv2d::new(3, ch, ch, 2, 1, true, rng)),
            Box::new(LeakyRelu::new(0.1 + rng.uniform() as f32 * 0.4)),
            Box::new(MaxPool2d::new(2)),
            Box::new(Dense::new(hw * hw * ch, ch, true, rng)),
        ];
        for layer in &layers {
            let x = Tensor::randn(&[2, hw, hw, ch], 1.0, rng);
            let (y, res) = layer.forward_res(&x, ResidualKind::Full);
            let hp = Tensor::randn(y.shape(), 1.0, rng);
            let u = Tensor::randn(x.shape(), 1.0, rng);
            let lhs = moonwalk::tensor::ops::dot(&layer.vjp_input(&res, &hp), &u);
            let rhs = moonwalk::tensor::ops::dot(&hp, &layer.jvp_input(&x, &u));
            let scale = rhs.abs().max(1.0);
            assert!(
                (lhs - rhs).abs() / scale < 1e-3,
                "{}: adjoint {lhs} vs {rhs}",
                layer.name()
            );
        }
    });
}

/// Submersive projection is idempotent and always yields a Lemma-1
/// compliant layer, for random geometries.
#[test]
fn prop_projection_idempotent() {
    for_random_cases(400, 40, |rng| {
        let s = rng.int_range(2, 4);
        let p = rng.int_range(0, s.min(2));
        let k = rng.int_range(p + 1, p + 4);
        let cout = rng.int_range(1, 6);
        let cin = cout + rng.int_range(0, 2);
        let mut conv = Conv2d::new(k, cin, cout, s, p, false, rng);
        conv.project_submersive();
        assert!(conv.submersivity().is_submersive(), "{}", conv.name());
        let snap = conv.w.clone();
        conv.project_submersive();
        assert_eq!(conv.w, snap, "projection must be idempotent");
    });
}

/// The allocation tracker balances: live bytes return to baseline after
/// arbitrary engine runs (no leaks in any engine). Pinned to one thread
/// and warmed per engine: a cold `tensor::arena` miss inside the
/// measured region registers bytes that stay (pooled) live — recycling,
/// not a leak — and the parallel paths lease several buffers at once,
/// so the measured run must start from a steady-state arena.
#[test]
fn prop_tracker_conservation_across_engines() {
    use moonwalk::autodiff::engine_by_name;
    use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
    use moonwalk::nn::MeanLoss;
    let _pin = pin_lock();
    for_random_cases(500, 10, |rng| {
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: rng.int_range(1, 4),
            channels: rng.int_range(2, 6),
            cin: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, rng);
        let x = Tensor::randn(&[1, 16, 16, 2], 1.0, rng);
        pool::with_threads(1, || {
            for name in ["backprop", "backprop_ckpt", "moonwalk", "moonwalk_ckpt"] {
                let engine = engine_by_name(name, 4, 0, 0).unwrap();
                let _lock = tracker::measure_lock();
                // Unmeasured warm-up: populate the arena's free list so
                // the measured run below is allocation-steady.
                engine
                    .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
                    .unwrap();
                let live0 = tracker::current();
                engine
                    .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
                    .unwrap();
                assert_eq!(
                    tracker::current(),
                    live0,
                    "{name} leaked tracked bytes"
                );
            }
        });
    });
}

/// Non-submersive configurations must be *detected*, not silently
/// mis-differentiated (failure injection).
#[test]
fn prop_violations_detected() {
    for_random_cases(600, 30, |rng| {
        let (mut conv, x) = random_submersive_conv2d(rng);
        let (_, res) = conv.forward_res(&x, ResidualKind::Minimal);
        // Break one constraint at random.
        let h = Tensor::randn(x.shape(), 1.0, rng);
        match rng.below(2) {
            0 => {
                // zero a diagonal pivot
                let co = rng.below(conv.cout);
                let idx = ((conv.pad * conv.k + conv.pad) * conv.cin + co) * conv.cout + co;
                conv.w.data_mut()[idx] = 0.0;
            }
            _ => {
                if conv.cout >= 2 {
                    // violate triangularity
                    let idx = ((conv.pad * conv.k + conv.pad) * conv.cin + 0) * conv.cout
                        + (conv.cout - 1);
                    conv.w.data_mut()[idx] = 1.0;
                } else {
                    let idx = ((conv.pad * conv.k + conv.pad) * conv.cin) * conv.cout;
                    conv.w.data_mut()[idx] = 0.0;
                }
            }
        }
        assert!(!conv.submersivity().is_submersive());
        assert!(conv.vijp(&res, &h).is_err(), "{}", conv.name());
    });
}

/// Parallel Alg.-3 fragment reconstruction is **bit-identical** to the
/// serial kernel across random fragmental geometries (k, B, channels,
/// length, batch): blocks are independent and each (image, block) task
/// runs the identical serial recurrence, so the persistent pool's
/// span fan-out must not change a single bit.
#[test]
fn prop_fragment_reconstruct_parallel_bit_identical() {
    let _pin = pin_lock();
    for_random_cases(800, 25, |rng| {
        let k = rng.int_range(2, 5);
        let cout = rng.int_range(1, 6);
        let cin = cout + rng.int_range(0, 3);
        let conv = Conv1d::new_fragmental(k, cin, cout, rng);
        let block = k + rng.int_range(0, 10);
        let l = rng.int_range(block + 1, 4 * block + 2);
        let n = rng.int_range(1, 4);
        let x = Tensor::randn(&[n, l, cin], 1.0, rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let hp = Tensor::randn(y.shape(), 1.0, rng);
        let h = conv.vjp_input(&res, &hp);
        let frag = conv.fragment_capture(&hp, block).unwrap();
        let serial = pool::with_threads(1, || conv.fragment_reconstruct(&frag, &h).unwrap());
        for t in [2usize, 4] {
            let par = pool::with_threads(t, || conv.fragment_reconstruct(&frag, &h).unwrap());
            assert_eq!(
                serial.data(),
                par.data(),
                "{} B={block} n={n} L={l} t={t}: parallel reconstruction diverged",
                conv.name()
            );
        }
    });
}

/// Batch-1 spatial (row-band) conv2d: the parallel forward is
/// bit-identical to the serial kernel (disjoint row bands, same tap
/// order); the banded `vjp_params` matches to fp tolerance (the band
/// merge reorders the position sum — same contract as the batch-axis
/// reduction) and is bit-stable at a fixed thread count. The input is
/// sized past the spatial minimum-work floor (`H'·W'·Cout·k² ≥ 4096`)
/// so the row-band paths actually engage; below the floor the serial
/// kernel runs on both sides and the assertions hold trivially.
#[test]
fn prop_spatial_conv2d_batch1_parallel_matches_serial() {
    let _pin = pin_lock();
    for_random_cases(900, 25, |rng| {
        let (conv, xb) = random_submersive_conv2d(rng);
        let cin = xb.shape()[3];
        let (k, s, p, cout) = (conv.k, conv.stride, conv.pad, conv.cout);
        // Smallest H' with H'·W'·Cout·k² ≥ 4096 (and ≥ 4 rows to band),
        // then the input size that produces it exactly: H = s(H'−1)+k−2p
        // (> s(H'−1) since k > 2p, so the Lemma-1 spatial bound holds).
        let per = cout * k * k;
        let mut ho = 4usize;
        while ho * ho * per < 4096 {
            ho += 1;
        }
        let hw = s * (ho - 1) + k - 2 * p;
        let x = Tensor::randn(&[1, hw, hw, cin], 1.0, rng);
        let (y, _res) = conv.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, rng);
        let (y1, dw1) =
            pool::with_threads(1, || (conv.forward(&x), conv.vjp_params(&x, &g)));
        for t in [2usize, 4] {
            let (yt, dwt) =
                pool::with_threads(t, || (conv.forward(&x), conv.vjp_params(&x, &g)));
            assert_eq!(
                y1.data(),
                yt.data(),
                "{} t={t}: spatial forward must be bit-identical",
                conv.name()
            );
            for (a, b) in dw1.iter().zip(&dwt) {
                let err = rel_err(b, a);
                assert!(
                    err <= 1e-5,
                    "{} t={t}: spatial vjp_params rel err {err}",
                    conv.name()
                );
            }
            // Bit-stability at a fixed count: rerun and compare bits.
            let (yt2, dwt2) =
                pool::with_threads(t, || (conv.forward(&x), conv.vjp_params(&x, &g)));
            assert_eq!(yt.data(), yt2.data());
            for (a, b) in dwt.iter().zip(&dwt2) {
                assert_eq!(a.data(), b.data(), "{} t={t}: dw not bit-stable", conv.name());
            }
        }
    });
}

/// Batch-1 `transpose_conv` (the `vjp_input` scatter) parallelizes over
/// **input-row bands** with banded accumulation — the first ROADMAP
/// follow-up of the persistent-runtime PR. Unlike the band-reduced
/// `vjp_params`, the banded scatter visits every (tap, position)
/// contribution of an output element in exactly the serial order, so the
/// parallel result must be **bit-identical** to the serial one at every
/// thread count (and trivially bit-stable).
#[test]
fn prop_spatial_conv2d_batch1_transpose_conv_bit_identical() {
    let _pin = pin_lock();
    for_random_cases(950, 25, |rng| {
        let (conv, xb) = random_submersive_conv2d(rng);
        let cin = xb.shape()[3];
        let (k, s, p, cout) = (conv.k, conv.stride, conv.pad, conv.cout);
        // Size past the spatial minimum-work floor, as in the
        // forward/vjp_params property above.
        let per = cout * k * k;
        let mut ho = 4usize;
        while ho * ho * per < 4096 {
            ho += 1;
        }
        let hw = s * (ho - 1) + k - 2 * p;
        let x = Tensor::randn(&[1, hw, hw, cin], 1.0, rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, rng);
        let h1 = pool::with_threads(1, || conv.vjp_input(&res, &g));
        for t in [2usize, 4] {
            let ht = pool::with_threads(t, || conv.vjp_input(&res, &g));
            assert_eq!(
                h1.data(),
                ht.data(),
                "{} t={t}: banded transpose_conv must be bit-identical",
                conv.name()
            );
        }
    });
}

/// Batch-1 **vijp** spatial fast path (the last open PR-1 follow-up):
/// with no spatial coupling (`s + p ≥ k`, Alg. 2) every output position
/// solves independently, so the row-band fan-out via `pool::run_spans`
/// must be **bit-identical** to the serial elimination at every thread
/// count — the gather/solve/scatter arithmetic per position is the same
/// code restricted to a band. Inputs are sized past the spatial
/// minimum-work floor so the banded path actually engages; the
/// wavefront regime (`s + p < k`) stays serial at batch 1 and is
/// covered by the existing right-inverse properties.
#[test]
fn prop_spatial_conv2d_batch1_vijp_bit_identical() {
    let _pin = pin_lock();
    for_random_cases(1000, 25, |rng| {
        let (conv, xb) = random_submersive_conv2d(rng);
        if !conv.vijp_fast_path() {
            return; // spatially coupled: no banded path to compare
        }
        let cin = xb.shape()[3];
        let (k, s, p, cout) = (conv.k, conv.stride, conv.pad, conv.cout);
        // Size past the floor exactly as the sibling spatial properties.
        let per = cout * k * k;
        let mut ho = 4usize;
        while ho * ho * per < 4096 {
            ho += 1;
        }
        let hw = s * (ho - 1) + k - 2 * p;
        let x = Tensor::randn(&[1, hw, hw, cin], 1.0, rng);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let hp = Tensor::randn(y.shape(), 1.0, rng);
        let h = conv.vjp_input(&res, &hp);
        let serial = pool::with_threads(1, || conv.vijp(&res, &h).unwrap());
        for t in [2usize, 4] {
            let par = pool::with_threads(t, || conv.vijp(&res, &h).unwrap());
            assert_eq!(
                serial.data(),
                par.data(),
                "{} t={t}: banded vijp must be bit-identical",
                conv.name()
            );
        }
        // And it still inverts vjp_input on the row space (the Moonwalk
        // correctness oracle), banded or not.
        assert!(rel_err(&serial, &hp) < 5e-2, "{}", conv.name());
    });
}

/// Pooling vijp right-inverse for random even geometries.
#[test]
fn prop_pool_vijp() {
    for_random_cases(700, 25, |rng| {
        let q = rng.int_range(2, 4);
        let hw = q * rng.int_range(2, 5);
        let pool = MaxPool2d::new(q);
        let x = Tensor::randn(&[rng.int_range(1, 3), hw, hw, rng.int_range(1, 4)], 1.0, rng);
        let (y, res) = pool.forward_res(&x, ResidualKind::Minimal);
        let hp = Tensor::randn(y.shape(), 1.0, rng);
        let h = pool.vjp_input(&res, &hp);
        let rec = pool.vijp(&res, &h).unwrap();
        assert!(rel_err(&rec, &hp) < 1e-5);
    });
}

/// The reversible-family contract: across randomized layers and shapes,
/// `is_submersive()` must agree with what `vijp` actually does — a
/// submersive layer's `vijp ∘ vjp_input` round-trips the output
/// cotangent, and a non-submersive layer's `vijp` returns a named
/// [`moonwalk::nn::LayerError`] (never wrong numbers, never a panic).
#[test]
fn prop_submersivity_flag_matches_vijp_behaviour() {
    use moonwalk::nn::{CouplingBlock, MomentumBlock, ResidualBlock, Upsample};
    // A well-conditioned random Dense: the diagonal boost keeps the
    // vijp's Gram solve far from the rank-deficiency certification edge,
    // so the submersivity flag is the only thing under test.
    fn dense(rng: &mut Rng, din: usize, dout: usize) -> Box<Dense> {
        let mut d = Dense::new(din, dout, rng.bernoulli(0.5), rng);
        for i in 0..din.min(dout) {
            d.w.data_mut()[i * dout + i] += 1.5;
        }
        Box::new(d)
    }
    for_random_cases(900, 60, |rng| {
        let batch = rng.int_range(1, 3);
        let half = rng.int_range(1, 5);
        let width = half * 2;
        let gamma = [0.6f32, 0.8, 1.0][rng.int_range(0, 3)];
        let (layer, x): (Box<dyn Layer>, Tensor) = match rng.int_range(0, 9) {
            0 => {
                // Square-or-wide Dense: submersive.
                let dout = rng.int_range(1, width + 1);
                (dense(rng, width, dout), Tensor::randn(&[batch, width], 1.0, rng))
            }
            1 => {
                // Widening Dense: non-submersive.
                let dout = width + rng.int_range(1, 4);
                (dense(rng, width, dout), Tensor::randn(&[batch, width], 1.0, rng))
            }
            2 => (
                Box::new(LeakyRelu::new(0.1)),
                Tensor::randn(&[batch, width], 1.0, rng),
            ),
            3 => {
                let (conv, x) = random_submersive_conv2d(rng);
                (Box::new(conv) as Box<dyn Layer>, x)
            }
            4 => {
                // s = 1 ≤ p = 1 breaks Lemma 1: non-submersive.
                let cout = rng.int_range(1, 4);
                let cin = cout + rng.int_range(0, 3);
                let conv = Conv1d::new_fragmental(rng.int_range(2, 5), cin, cout, rng);
                let len = rng.int_range(8, 16);
                (Box::new(conv) as Box<dyn Layer>, Tensor::randn(&[batch, len, cin], 1.0, rng))
            }
            5 => (
                Box::new(MaxPool2d::new(2)),
                Tensor::randn(&[batch, 4, 4, rng.int_range(1, 4)], 1.0, rng),
            ),
            6 => {
                // Expanding map: non-submersive.
                let cin = rng.int_range(1, 4);
                let cout = cin + rng.int_range(1, 3);
                (
                    Box::new(Upsample::new(cin, cout)),
                    Tensor::randn(&[batch, 4, 4, cin], 1.0, rng),
                )
            }
            7 => (
                Box::new(ResidualBlock::new(dense(rng, half, half))),
                Tensor::randn(&[batch, width], 1.0, rng),
            ),
            _ => {
                let block: Box<dyn Layer> = if rng.bernoulli(0.5) {
                    Box::new(CouplingBlock::new(
                        dense(rng, half, half),
                        dense(rng, half, half),
                    ))
                } else {
                    Box::new(MomentumBlock::new(dense(rng, half, half), gamma))
                };
                (block, Tensor::randn(&[batch, width], 1.0, rng))
            }
        };
        let (y, res) = layer.forward_res(&x, ResidualKind::Minimal);
        let hp = Tensor::randn(y.shape(), 1.0, rng);
        let h = layer.vjp_input(&res, &hp);
        match (layer.submersivity().is_submersive(), layer.vijp(&res, &h)) {
            (true, Ok(rec)) => {
                let err = rel_err(&rec, &hp);
                assert!(err < 5e-2, "{}: round-trip rel err {err}", layer.name());
            }
            (true, Err(e)) => panic!("{}: submersive flag but vijp failed: {e}", layer.name()),
            (false, Ok(_)) => panic!("{}: non-submersive flag but vijp succeeded", layer.name()),
            (false, Err(e)) => {
                let msg = format!("{e}");
                assert!(
                    msg.contains(&layer.name()),
                    "{}: error must name the layer: {msg}",
                    layer.name()
                );
            }
        }
    });
}
