//! Live telemetry plane contract (ISSUE 10):
//!
//! 1. **Fleet aggregation over the wire** — scraping `/metrics` during
//!    a 2-replica unix-transport train returns per-replica-labeled
//!    series (the workers' piggybacked `step.seconds` histograms and
//!    the coordinator's `transport.step_seconds`), rendered in valid
//!    Prometheus text exposition v0.0.4.
//! 2. **Histogram correctness** — `_bucket` series are cumulative and
//!    monotone across the whole ladder, the `+Inf` bucket equals
//!    `_count`, and every bound appears exactly once per series.
//! 3. **Snapshot schema stability** — `/snapshot` keeps the flat JSON
//!    shape trainer JSONL rows and `BENCH_perf_ops.json` embed: plain
//!    numbers for counters/gauges, `{count, sum, min, max, mean}`
//!    sub-objects for histograms, live pool/arena/tracker sources
//!    always present.
//! 4. **Scrape determinism** — the §2.6 zero-effect-on-results
//!    contract extends to scraping mid-run: the full `EXACT_ENGINES`
//!    grid produces bit-identical loss curves with a scraper hammering
//!    `/metrics` + `/snapshot` vs no scraper at all.
//!
//! The metrics registry is process-global, so every test serializes
//! through one mutex and resets the registry while holding it. The
//! listener thread is process-lived; all tests share one ephemeral-port
//! server.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use moonwalk::autodiff::{engine_by_name, EXACT_ENGINES};
use moonwalk::coordinator::{Optimizer, OptimizerKind, SyntheticSpec, TextureDataset, Trainer};
use moonwalk::distributed::transport::{
    supervisor, EngineSpec, FaultPlan, UnixTransport, UnixTransportOpts,
};
use moonwalk::model::config::Config;
use moonwalk::obs::http;
use moonwalk::obs::metrics::{self, BUCKET_BOUNDS};
use moonwalk::util::json::Json;
use moonwalk::util::Rng;

/// Serializes every test: the metrics registry is process-global.
static REGISTRY_LOCK: Mutex<()> = Mutex::new(());

fn registry_lock() -> std::sync::MutexGuard<'static, ()> {
    match REGISTRY_LOCK.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// One shared ephemeral-port listener (the serve thread is
/// process-lived by design, so binding once keeps the footprint small).
fn server() -> SocketAddr {
    static ADDR: OnceLock<SocketAddr> = OnceLock::new();
    *ADDR.get_or_init(|| http::serve("127.0.0.1:0").expect("bind ephemeral port"))
}

/// The tiny CNN config the transport suite uses, so worker subprocesses
/// can rebuild the identical architecture.
fn tiny_cfg(seed: u64) -> Config {
    Config::from_json(
        &Json::parse(&format!(
            r#"{{"arch": "cnn2d", "depth": 2, "channels": 5, "input_hw": 16,
                 "cin": 2, "classes": 4, "alpha": 0.1, "constrained": true,
                 "seed": {seed}}}"#
        ))
        .unwrap(),
    )
    .unwrap()
}

/// A spawned 2-replica unix transport pointed at the built binary.
fn unix_transport(cfg: &Config, engine: EngineSpec) -> UnixTransport {
    let mut opts = UnixTransportOpts::new(2, cfg.to_json().to_string(), engine);
    opts.worker_bin = Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_moonwalk")));
    UnixTransport::spawn(opts).expect("spawn unix transport")
}

/// Start a background scraper that hammers `/metrics` and `/snapshot`
/// until the returned stop flag is raised (drop the handle via
/// `join` after raising it).
fn spawn_scraper(addr: SocketAddr) -> (Arc<AtomicBool>, std::thread::JoinHandle<u64>) {
    let stop = Arc::new(AtomicBool::new(false));
    let flag = stop.clone();
    let handle = std::thread::spawn(move || {
        let mut scrapes = 0u64;
        while !flag.load(Ordering::Relaxed) {
            let (code, _) = http::get(addr, "/metrics").expect("scrape /metrics");
            assert_eq!(code, 200);
            let (code, _) = http::get(addr, "/snapshot").expect("scrape /snapshot");
            assert_eq!(code, 200);
            scrapes += 1;
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        scrapes
    });
    (stop, handle)
}

/// Validate the whole body against the text exposition grammar: every
/// non-comment line is `name[{labels}] value` with a legal metric name,
/// balanced `k="v"` label pairs, and a parseable value (`NaN`/`±Inf`
/// included — Rust's float parser accepts all three spellings).
fn assert_exposition_grammar(text: &str) {
    let mut series = 0usize;
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split(' ');
            let (name, kind) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
            assert!(!name.is_empty(), "TYPE line without a name: {line}");
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "unknown TYPE kind: {line}"
            );
            continue;
        }
        if line.starts_with('#') {
            // Non-TYPE comments (e.g. the mixed-kind skip note) are
            // legal exposition; scrapers ignore them.
            continue;
        }
        let (key, value) = line
            .rsplit_once(' ')
            .unwrap_or_else(|| panic!("no value on sample line: {line:?}"));
        value
            .parse::<f64>()
            .unwrap_or_else(|_| panic!("unparseable value {value:?} on: {line}"));
        let name_end = key.find('{').unwrap_or(key.len());
        let name = &key[..name_end];
        assert!(
            !name.is_empty()
                && !name.starts_with(|c: char| c.is_ascii_digit())
                && name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "illegal metric name on: {line}"
        );
        if name_end < key.len() {
            assert!(key.ends_with('}'), "unterminated label set: {line}");
            let body = &key[name_end + 1..key.len() - 1];
            for pair in body.split(',') {
                let (k, v) = pair
                    .split_once('=')
                    .unwrap_or_else(|| panic!("label without '=' on: {line}"));
                assert!(
                    !k.is_empty() && v.len() >= 2 && v.starts_with('"') && v.ends_with('"'),
                    "label {pair:?} is not k=\"v\" on: {line}"
                );
            }
        }
        series += 1;
    }
    assert!(series > 0, "exposition body is empty");
}

/// Walk one labeled histogram's bucket ladder: cumulative counts must
/// be monotone, every bound plus `+Inf` appears exactly once, and the
/// `+Inf` bucket is returned for comparison against `_count`.
fn assert_bucket_ladder(text: &str, bucket_prefix: &str) -> u64 {
    let mut last = 0u64;
    let mut seen = 0usize;
    let mut inf = None;
    for line in text.lines() {
        let Some(rest) = line.strip_prefix(bucket_prefix) else {
            continue;
        };
        let v: u64 = rest
            .rsplit_once(' ')
            .and_then(|(_, v)| v.parse().ok())
            .unwrap_or_else(|| panic!("bad bucket line: {line}"));
        assert!(v >= last, "bucket counts must be cumulative: {line}");
        last = v;
        seen += 1;
        if rest.starts_with("\"+Inf\"") {
            inf = Some(v);
        }
    }
    assert_eq!(
        seen,
        BUCKET_BOUNDS.len() + 1,
        "{bucket_prefix}: every bound plus +Inf appears once"
    );
    inf.unwrap_or_else(|| panic!("{bucket_prefix}: no +Inf bucket"))
}

/// Grab one sample's value by its exact series key.
fn sample(text: &str, key: &str) -> Option<f64> {
    let prefix = format!("{key} ");
    text.lines()
        .find_map(|l| l.strip_prefix(&prefix))
        .and_then(|v| v.parse().ok())
}

// ---------------------------------------------------------------------------
// 1 + 2. Live 2-replica scrape: per-replica series, valid exposition,
//        cumulative buckets
// ---------------------------------------------------------------------------

#[test]
fn two_replica_unix_train_scrape_exposes_per_replica_series() {
    let _g = registry_lock();
    metrics::reset();
    let addr = server();
    let (stop, scraper) = spawn_scraper(addr);

    let cfg = tiny_cfg(21);
    let mut rng = Rng::new(cfg.seed);
    let mut net = cfg.build_network(&mut rng);
    let data = TextureDataset::generate(
        SyntheticSpec {
            hw: 16,
            cin: 2,
            classes: 4,
            noise: 0.15,
            seed: 21,
        },
        40,
    );
    let (train, test) = data.split(0.2);
    let engine = engine_by_name("moonwalk", cfg.block, cfg.checkpoint_every, cfg.seed).unwrap();
    let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
    let steps = 3;
    let mut trainer = Trainer::new(&mut net, engine.as_ref(), opt);
    trainer.replicas = 2;
    trainer.transport = Some(Box::new(unix_transport(&cfg, EngineSpec::new("moonwalk"))));
    let report = trainer
        .train(&train, &test, 4, steps, &mut Rng::new(22), None)
        .unwrap();
    assert_eq!(report.transport, "unix");

    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().expect("scraper thread");
    assert!(scrapes > 0, "the scraper ran during the train");

    let (code, body) = http::get(addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    assert_exposition_grammar(&body);

    // Workers piggyback their step.seconds over the wire; the
    // coordinator folds each under its logical shard's replica label.
    assert!(
        body.contains("# TYPE moonwalk_step_seconds histogram"),
        "fleet histogram family missing:\n{body}"
    );
    for replica in ["0", "1"] {
        let count = sample(&body, &format!("moonwalk_step_seconds_count{{replica=\"{replica}\"}}"))
            .unwrap_or_else(|| panic!("no step.seconds count for replica {replica}:\n{body}"));
        assert!(
            count >= steps as f64,
            "replica {replica} reported {count} steps, ran {steps}"
        );
        // Coordinator-side wall time per logical shard rides along.
        assert!(
            sample(
                &body,
                &format!("moonwalk_transport_step_seconds_count{{replica=\"{replica}\"}}")
            )
            .is_some(),
            "no transport.step_seconds for replica {replica}:\n{body}"
        );
        let inf = assert_bucket_ladder(
            &body,
            &format!("moonwalk_step_seconds_bucket{{replica=\"{replica}\",le="),
        );
        assert_eq!(inf as f64, count, "+Inf bucket equals _count");
    }
    // The trainer's own unlabeled step histogram and the live sources
    // render in the same scrape.
    assert!(sample(&body, "moonwalk_train_step_seconds_count").is_some());
    assert!(body.contains("# TYPE moonwalk_tracker_peak_bytes gauge"));

    // A just-finished run reads healthy.
    let (code, health) = http::get(addr, "/healthz").unwrap();
    assert_eq!(code, 200, "{health}");
    assert!(health.starts_with("ok"), "{health}");
}

// ---------------------------------------------------------------------------
// 1b. Straggler flagging mid-train must complete (deadlock regression)
// ---------------------------------------------------------------------------

/// Regression: the straggler warning used to re-lock the tracker mutex
/// inside the eagerly-formatted `log_warn!` arguments while the guard
/// from the same statement's first lock was still alive — a guaranteed
/// self-deadlock of the non-reentrant `std::sync::Mutex` the moment any
/// replica was flagged, hanging the drive thread and with it the whole
/// run. Force a flag deterministically — low z threshold plus one
/// delayed gradient frame well past the 8-sample warm-up — and assert
/// the run completes and reports the flag everywhere it should.
#[test]
fn straggler_flag_mid_train_completes_and_is_reported() {
    let _g = registry_lock();
    metrics::reset();
    supervisor::set_straggler_z(0.5);

    let cfg = tiny_cfg(29);
    let mut rng = Rng::new(cfg.seed);
    let mut net = cfg.build_network(&mut rng);
    let data = TextureDataset::generate(
        SyntheticSpec {
            hw: 16,
            cin: 2,
            classes: 4,
            noise: 0.15,
            seed: 29,
        },
        48,
    );
    let (train, test) = data.split(0.2);
    let engine = engine_by_name("moonwalk", cfg.block, cfg.checkpoint_every, cfg.seed).unwrap();
    let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
    let mut trainer = Trainer::new(&mut net, engine.as_ref(), opt);
    trainer.replicas = 2;
    // Steps 0..=5 of 2 replicas give 12 warm-up samples; the 150 ms
    // frame delay at step 6 then makes replica 1's step a guaranteed
    // z-outlier against tiny-net step-time jitter.
    let mut opts = UnixTransportOpts::new(2, cfg.to_json().to_string(), EngineSpec::new("moonwalk"));
    opts.worker_bin = Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_moonwalk")));
    opts.faults = FaultPlan::parse("delay150:1@6").unwrap();
    trainer.transport = Some(Box::new(
        UnixTransport::spawn(opts).expect("spawn unix transport"),
    ));
    let result = trainer.train(&train, &test, 4, 8, &mut Rng::new(30), None);
    supervisor::set_straggler_z(supervisor::DEFAULT_STRAGGLER_Z);

    let report = result.expect("a flagged straggler must not hang or fail the run");
    assert_eq!(report.transport, "unix");
    assert!(
        report.stragglers >= 1,
        "the delayed replica must be flagged in TrainReport, got {}",
        report.stragglers
    );
    assert!(metrics::counter("supervisor.stragglers") >= 1);
    assert!(
        metrics::counter("supervisor.stragglers{replica=\"1\"}") >= 1,
        "the per-replica flag counter must name the delayed replica"
    );
}

// ---------------------------------------------------------------------------
// 3. /snapshot schema stability
// ---------------------------------------------------------------------------

#[test]
fn snapshot_schema_is_stable() {
    let _g = registry_lock();
    metrics::reset();
    let addr = server();
    metrics::counter_add("itest.snap.count", 7);
    metrics::gauge_set("itest.snap.gauge", 2.5);
    metrics::observe_labeled("step.seconds", &[("replica", "0")], 0.25);
    metrics::observe_labeled("step.seconds", &[("replica", "0")], 0.75);

    let (code, body) = http::get(addr, "/snapshot").unwrap();
    assert_eq!(code, 200);
    let snap = Json::parse(&body).expect("snapshot is valid JSON");

    // Live sources are always present as plain numbers.
    for key in [
        "pool.regions",
        "arena.hits",
        "arena.misses",
        "tracker.current_bytes",
        "tracker.peak_bytes",
        "tracker.total_allocs",
        "tracker.total_frees",
    ] {
        assert!(snap.get(key).as_f64().is_some(), "live source {key} missing");
    }
    // Counters and gauges stay flat numbers.
    assert_eq!(snap.get("itest.snap.count").as_usize(), Some(7));
    assert_eq!(snap.get("itest.snap.gauge").as_f64(), Some(2.5));
    // Labeled histograms keep the documented sub-object under their
    // composite key — the shape JSONL rows and perf_ops embed.
    let h = snap.get("step.seconds{replica=\"0\"}");
    assert_eq!(h.req_usize("count").unwrap(), 2);
    assert_eq!(h.req_f64("sum").unwrap(), 1.0);
    assert_eq!(h.req_f64("min").unwrap(), 0.25);
    assert_eq!(h.req_f64("max").unwrap(), 0.75);
    assert_eq!(h.req_f64("mean").unwrap(), 0.5);
}

// ---------------------------------------------------------------------------
// 4. Scraping never perturbs the computation
// ---------------------------------------------------------------------------

#[test]
fn exact_engine_grid_loss_curves_bit_identical_scraped_vs_not() {
    let _g = registry_lock();
    metrics::reset();
    let addr = server();
    let cfg = tiny_cfg(23);
    let data = TextureDataset::generate(
        SyntheticSpec {
            hw: 16,
            cin: 2,
            classes: 4,
            noise: 0.15,
            seed: 23,
        },
        32,
    );
    let (train, test) = data.split(0.25);

    for name in EXACT_ENGINES {
        let run = || {
            let mut rng = Rng::new(cfg.seed);
            let mut net = cfg.build_network(&mut rng);
            let engine = engine_by_name(name, cfg.block, cfg.checkpoint_every, cfg.seed).unwrap();
            let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
            let mut trainer = Trainer::new(&mut net, engine.as_ref(), opt);
            trainer
                .train(&train, &test, 4, 4, &mut Rng::new(24), None)
                .unwrap()
        };
        let quiet = run();
        let (stop, scraper) = spawn_scraper(addr);
        let scraped = run();
        stop.store(true, Ordering::Relaxed);
        let scrapes = scraper.join().expect("scraper thread");
        assert!(scrapes > 0, "{name}: the scraper ran during the train");

        assert_eq!(
            quiet.loss_curve.len(),
            scraped.loss_curve.len(),
            "{name}: curve length"
        );
        for (step, (a, b)) in quiet.loss_curve.iter().zip(&scraped.loss_curve).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{name} step {step}: loss must be bit-identical under scraping ({a} vs {b})"
            );
        }
        assert_eq!(
            quiet.final_loss.to_bits(),
            scraped.final_loss.to_bits(),
            "{name}: final loss bits"
        );
    }
}
