//! Conv algorithm dispatch properties (ISSUE 7):
//!
//! 1. Equivalence grid: every `ConvAlgo` lowering matches the Direct
//!    reference to ≤ 1e-5 for forward and `vjp_params` across tail
//!    blocks, the `s + p < k` wavefront geometry, and batch-1 shapes,
//!    at 1 and 4 threads. `vijp` has no alternative lowering, so a
//!    forced override must leave it bit-for-bit untouched.
//! 2. Determinism: a fixed `(algo, threads)` pair is bit-identical
//!    run-to-run.
//! 3. Autotune cache: a corrupt or stale cache file degrades to an
//!    empty table (re-timing, never an error), and two processes
//!    sharing one persisted cache file resolve identical algorithms and
//!    compile identical plans (simulated here with `reload()`, which
//!    drops all in-memory state exactly like a respawned worker).
//!
//! The override, cache path, and worker count are process-global, so
//! every test serializes through a local mutex and restores what it
//! changed via drop guards.

use std::sync::Mutex;

use moonwalk::nn::{Conv1d, Conv2d, Layer};
use moonwalk::runtime::pool;
use moonwalk::tensor::{assert_close, conv_algo, Tensor};
use moonwalk::util::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    match LOCK.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Restores the pool's thread count on drop (panic-safe).
struct ThreadGuard(usize);
impl Drop for ThreadGuard {
    fn drop(&mut self) {
        pool::set_threads(self.0);
    }
}

fn with_threads<T>(t: usize, f: impl FnOnce() -> T) -> T {
    let _guard = ThreadGuard(pool::threads());
    pool::set_threads(t);
    f()
}

/// Forces a conv algorithm until dropped, then restores `auto`.
struct ForcedConv;
impl ForcedConv {
    fn engage(name: &str) -> ForcedConv {
        conv_algo::set_conv_override(name).unwrap();
        ForcedConv
    }
}
impl Drop for ForcedConv {
    fn drop(&mut self) {
        let _ = conv_algo::set_conv_override("auto");
    }
}

fn temp_cache(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("moonwalk_conv_{}_{name}.json", std::process::id()))
}

/// Point the process cache at a fresh temp file; restores an empty
/// in-memory table on drop (the path itself stays — this test binary
/// owns the process — but every test re-points it before use).
struct CacheFile(std::path::PathBuf);
impl CacheFile {
    fn fresh(name: &str) -> CacheFile {
        let p = temp_cache(name);
        let _ = std::fs::remove_file(&p);
        conv_algo::set_cache_path(p.to_str().unwrap());
        conv_algo::reload();
        CacheFile(p)
    }
}
impl Drop for CacheFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
        conv_algo::reload();
    }
}

/// Every non-Direct lowering × {tail-block, wavefront `s+p<k`, batch-1,
/// stride-1 Winograd-eligible} geometries × threads {1, 4} matches the
/// Direct reference for forward and `vjp_params`; `vijp` is untouched
/// by the override (bit-for-bit).
#[test]
fn conv2d_lowerings_match_direct_across_grid() {
    let _g = lock();
    // (k, s, p, cin, cout, hw, n)
    for &(k, s, p, cin, cout, hw, n) in &[
        (3usize, 2usize, 1usize, 4usize, 4usize, 9usize, 3usize), // tail blocks
        (5, 3, 1, 3, 3, 13, 2),                                   // wavefront: s+p<k
        (3, 2, 1, 6, 3, 9, 1),                                    // batch-1 row-band
        (3, 1, 1, 4, 6, 11, 2),                                   // stride-1: Winograd applies
    ] {
        let mut rng = Rng::new(900 + k as u64 + s as u64);
        // vijp needs the submersive projection and a supported schedule
        // (fast path or the strided wavefront); the stride-1 row exists
        // for Winograd's forward/vjp_params coverage only.
        let check_vijp = s > 1;
        let conv = if check_vijp {
            Conv2d::new_submersive(k, cin, cout, s, p, true, &mut rng)
        } else {
            Conv2d::new(k, cin, cout, s, p, true, &mut rng)
        };
        let x = Tensor::randn(&[n, hw, hw, cin], 1.0, &mut rng);
        let (y, res) = conv.forward_res(&x, moonwalk::nn::ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &g);

        let (y_d, vw_d, vj_d) = {
            let _f = ForcedConv::engage("direct");
            with_threads(1, || {
                (
                    conv.forward(&x),
                    conv.vjp_params(&x, &g),
                    check_vijp.then(|| conv.vijp(&res, &h).unwrap()),
                )
            })
        };
        for algo in ["im2col", "winograd"] {
            for t in [1usize, 4] {
                let _f = ForcedConv::engage(algo);
                let (y_a, vw_a, vj_a) = with_threads(t, || {
                    (
                        conv.forward(&x),
                        conv.vjp_params(&x, &g),
                        check_vijp.then(|| conv.vijp(&res, &h).unwrap()),
                    )
                });
                let tag = format!("conv2d k{k}s{s}p{p} {cin}->{cout} n{n} {algo} t={t}");
                assert_close(&y_a, &y_d, 1e-5, &format!("{tag} fwd"));
                for (a, b) in vw_a.iter().zip(&vw_d) {
                    assert_close(a, b, 1e-5, &format!("{tag} vjp_params"));
                }
                // vijp has no alternative lowering: the override must
                // not change a single bit of its schedule at t=1.
                if t == 1 {
                    if let (Some(va), Some(vd)) = (&vj_a, &vj_d) {
                        assert_eq!(va.data(), vd.data(), "{tag} vijp untouched");
                    }
                }
            }
        }
    }
}

#[test]
fn conv1d_im2col_matches_direct_across_grid() {
    let _g = lock();
    // (k, s, p, cin, cout, l, n)
    for &(k, s, p, cin, cout, l, n) in &[
        (3usize, 2usize, 1usize, 4usize, 4usize, 11usize, 3usize), // tail blocks
        (5, 3, 1, 3, 3, 16, 2),                                    // wavefront geometry
        (3, 1, 1, 5, 5, 19, 1),                                    // batch-1, stride-1
    ] {
        let mut rng = Rng::new(950 + k as u64 + l as u64);
        let check_vijp = s > 1;
        let conv = if check_vijp {
            Conv1d::new_submersive(k, cin, cout, s, p, &mut rng)
        } else {
            Conv1d::new(k, cin, cout, s, p, false, &mut rng)
        };
        let x = Tensor::randn(&[n, l, cin], 1.0, &mut rng);
        let (y, res) = conv.forward_res(&x, moonwalk::nn::ResidualKind::Minimal);
        let g = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &g);

        let (y_d, vw_d, vj_d) = {
            let _f = ForcedConv::engage("direct");
            with_threads(1, || {
                (
                    conv.forward(&x),
                    conv.vjp_params(&x, &g),
                    check_vijp.then(|| conv.vijp(&res, &h).unwrap()),
                )
            })
        };
        for t in [1usize, 4] {
            let _f = ForcedConv::engage("im2col");
            let (y_a, vw_a, vj_a) = with_threads(t, || {
                (
                    conv.forward(&x),
                    conv.vjp_params(&x, &g),
                    check_vijp.then(|| conv.vijp(&res, &h).unwrap()),
                )
            });
            let tag = format!("conv1d k{k}s{s}p{p} n{n} im2col t={t}");
            assert_close(&y_a, &y_d, 1e-5, &format!("{tag} fwd"));
            for (a, b) in vw_a.iter().zip(&vw_d) {
                assert_close(a, b, 1e-5, &format!("{tag} vjp_params"));
            }
            if t == 1 {
                if let (Some(va), Some(vd)) = (&vj_a, &vj_d) {
                    assert_eq!(va.data(), vd.data(), "{tag} vijp untouched");
                }
            }
        }
    }
}

/// A fixed `(algo, threads)` pair is bit-identical run-to-run — the
/// dispatch layer adds no nondeterminism on top of the deterministic
/// kernels.
#[test]
fn fixed_algo_and_threads_bit_deterministic() {
    let _g = lock();
    for algo in ["direct", "im2col", "winograd"] {
        let _f = ForcedConv::engage(algo);
        let run = || {
            let mut rng = Rng::new(1234);
            let conv = Conv2d::new(3, 4, 6, 1, 1, true, &mut rng);
            let x = Tensor::randn(&[2, 11, 11, 4], 1.0, &mut rng);
            let y = conv.forward(&x);
            let g = Tensor::randn(y.shape(), 1.0, &mut rng);
            let dw = conv.vjp_params(&x, &g);
            (y, dw)
        };
        let (y_a, dw_a) = with_threads(4, run);
        let (y_b, dw_b) = with_threads(4, run);
        assert_eq!(y_a.data(), y_b.data(), "{algo} forward bit-identical");
        for (a, b) in dw_a.iter().zip(&dw_b) {
            assert_eq!(a.data(), b.data(), "{algo} vjp_params bit-identical");
        }
    }
}

/// Corrupt or version-stale cache files degrade to an empty table —
/// re-timing territory, never an error — and the next `record` rewrites
/// a loadable file.
#[test]
fn corrupt_or_stale_cache_falls_back_to_retiming() {
    let _g = lock();
    let cache = CacheFile::fresh("corrupt");
    std::fs::write(&cache.0, b"{ not json at all").unwrap();
    conv_algo::reload();
    assert_eq!(conv_algo::cache_len(), 0, "corrupt file loads as empty");

    std::fs::write(&cache.0, br#"{"version": 999, "entries": {}}"#).unwrap();
    conv_algo::reload();
    assert_eq!(conv_algo::cache_len(), 0, "stale version loads as empty");

    // Calibration proceeds normally on the empty table and the recorded
    // winner round-trips through the (rewritten) file.
    let mut rng = Rng::new(77);
    let conv = Conv2d::new(3, 3, 3, 1, 1, false, &mut rng);
    let x = Tensor::randn(&[2, 9, 9, 3], 1.0, &mut rng);
    let outcomes = conv.autotune_with(&x, 0, 1);
    assert!(!outcomes.is_empty());
    assert!(outcomes.iter().all(|o| !o.cached), "empty table means real timing");
    conv_algo::reload();
    assert!(
        conv_algo::cache_len() >= outcomes.len(),
        "record() rewrote a loadable cache file"
    );
}

/// Two processes sharing one persisted cache file resolve identical
/// algorithms and compile identical plan tables. Process B is simulated
/// by `reload()`: all in-memory state is dropped and everything comes
/// back from the shared file, exactly like a respawned replica worker.
#[test]
fn shared_cache_yields_identical_resolution_and_plans() {
    let _g = lock();
    use moonwalk::model::{build_cnn1d_fragmental, FragmentalCnn1dSpec};
    use moonwalk::plan;

    let _cache = CacheFile::fresh("shared");
    let mut rng = Rng::new(31);
    let spec = FragmentalCnn1dSpec {
        input_len: 40,
        channels: 4,
        depth: 2,
        ..Default::default()
    };
    let net = build_cnn1d_fragmental(&spec, &mut rng);
    let in_shape = [2usize, 40, 3];

    // Process A: calibrate, then compile a plan with the timed column.
    let outcomes_a = with_threads(2, || plan::calibrate_convs(&net, &in_shape)).unwrap();
    assert!(!outcomes_a.is_empty());
    let plan_a = with_threads(2, || -> anyhow::Result<String> {
        let mut probes = plan::probe_network(&net, &in_shape, plan::DEFAULT_FRAG_BLOCKS)?;
        plan::attach_timed(&net, &in_shape, &mut probes);
        Ok(plan::summary_table(&plan::compile(&probes, None)?, &probes))
    })
    .unwrap();

    // Process B: fresh in-memory state, same file. No re-timing — every
    // op is served cached — and the compiled plan table is identical.
    conv_algo::reload();
    let outcomes_b = with_threads(2, || plan::calibrate_convs(&net, &in_shape)).unwrap();
    assert_eq!(outcomes_a.len(), outcomes_b.len());
    for (a, b) in outcomes_a.iter().zip(&outcomes_b) {
        assert_eq!(a.key, b.key, "same op keys in both processes");
        assert_eq!(a.algo, b.algo, "same winner for {}", a.key);
        assert!(b.cached, "process B must be served from the shared file");
        assert_eq!(a.best_ms, b.best_ms, "cached ms is the recorded ms");
    }
    let plan_b = with_threads(2, || -> anyhow::Result<String> {
        let mut probes = plan::probe_network(&net, &in_shape, plan::DEFAULT_FRAG_BLOCKS)?;
        plan::attach_timed(&net, &in_shape, &mut probes);
        Ok(plan::summary_table(&plan::compile(&probes, None)?, &probes))
    })
    .unwrap();
    assert_eq!(plan_a, plan_b, "shared cache compiles identical plan tables");
}

/// The forced-override CLI surface: unknown names error, valid names
/// round-trip through `conv_override`.
#[test]
fn override_names_validated_and_visible() {
    let _g = lock();
    assert!(conv_algo::set_conv_override("fft").is_err());
    {
        let _f = ForcedConv::engage("winograd");
        assert_eq!(
            conv_algo::conv_override(),
            Some(conv_algo::ConvAlgo::Winograd)
        );
    }
    assert_eq!(conv_algo::conv_override(), None, "guard restored auto");
}
