//! Data-parallel replica-sharding contract (ISSUE 3):
//!
//! 1. **Reduce determinism** — exactly-associative payloads reduce
//!    bit-equal across replica counts {1, 2, 4} and independently of
//!    arrival order.
//! 2. **Gradient equivalence** — `ReplicaGroup` training with
//!    replicas = N is fp-equivalent (≤ 1e-5) to replicas = 1 at the same
//!    effective batch for every exact engine, and bit-identical
//!    run-to-run at fixed replica/thread counts.
//! 3. **Pipeline determinism** — the double-buffered prefetcher streams
//!    exactly the deterministic plan, and the global sample sequence is
//!    replica-count invariant.
//! 4. **Resilience** — a panicking replica re-raises on the caller and
//!    the persistent pool keeps serving; an erroring replica fails the
//!    step with its replica index.
//!
//! The pool thread count is process-global, so thread-pinning tests
//! serialize through a local mutex (same pattern as the other suites).

use std::sync::Mutex;

use moonwalk::autodiff::{engine_by_name, Backprop, GradEngine, EXACT_ENGINES};
use moonwalk::coordinator::{SyntheticSpec, TextureDataset};
use moonwalk::distributed::pipeline::{BatchPlan, Prefetcher};
use moonwalk::distributed::{
    split_batch, ReduceOp, ReplicaGroup, Shard, StreamingAllReduce,
};
use moonwalk::model::{build_cnn2d, Network, SubmersiveCnn2dSpec};
use moonwalk::nn::{Loss, MeanLoss, SoftmaxCrossEntropy};
use moonwalk::runtime::pool;
use moonwalk::tensor::{rel_err, Tensor};
use moonwalk::util::Rng;

/// Serializes the tests that pin the (process-global) pool thread count.
static THREAD_PIN: Mutex<()> = Mutex::new(());

fn pin_lock() -> std::sync::MutexGuard<'static, ()> {
    match THREAD_PIN.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn tiny_cnn(seed: u64) -> Network {
    let mut rng = Rng::new(seed);
    let spec = SubmersiveCnn2dSpec {
        input_hw: 16,
        depth: 2,
        channels: 5,
        cin: 2,
        classes: 4,
        ..Default::default()
    };
    build_cnn2d(&spec, &mut rng)
}

// ---------------------------------------------------------------------------
// 1. Streaming all-reduce determinism
// ---------------------------------------------------------------------------

/// Exactly-associative payloads (small integers, equal splits by powers
/// of two) must reduce **bit-equal** across replica counts {1, 2, 4}:
/// the fold is replica-ordered and Mean's divide is exact, so the only
/// way this fails is a nondeterministic or arrival-ordered reduction.
#[test]
fn allreduce_bit_equal_across_replica_counts() {
    let depth = 3usize;
    // Per-layer global payload: distinct small integers per element.
    let global = |layer: usize| -> Vec<f32> {
        (0..8).map(|e| (layer * 64 + e * 4 + 8) as f32).collect()
    };
    let reduce_with = |replicas: usize, op: ReduceOp| -> Vec<Vec<f32>> {
        let r = StreamingAllReduce::new(depth, replicas, op);
        let mut out: Vec<Option<Vec<f32>>> = vec![None; depth];
        for layer in 0..depth {
            let g = global(layer);
            for rep in 0..replicas {
                let part: Vec<f32> = match op {
                    // Sum: equal exact splits of the global payload.
                    ReduceOp::Sum => g.iter().map(|v| v / replicas as f32).collect(),
                    // Mean: every replica holds the full payload.
                    ReduceOp::Mean => g.clone(),
                };
                let t = Tensor::from_vec(part, &[g.len()]);
                if let Some(red) = r.submit(layer, rep, vec![t]) {
                    out[layer] = Some(red[0].data().to_vec());
                }
            }
        }
        assert_eq!(r.reduced_layers(), depth);
        assert_eq!(r.pending_layers(), 0);
        out.into_iter().map(|o| o.expect("layer reduced")).collect()
    };
    for op in [ReduceOp::Sum, ReduceOp::Mean] {
        let one = reduce_with(1, op);
        for replicas in [2usize, 4] {
            let many = reduce_with(replicas, op);
            for (layer, (a, b)) in one.iter().zip(&many).enumerate() {
                assert_eq!(
                    a, b,
                    "{op:?} layer {layer}: replicas=1 vs {replicas} must be bit-equal"
                );
            }
        }
        // And every reduced layer equals the global payload exactly.
        for (layer, a) in one.iter().enumerate() {
            assert_eq!(a, &global(layer));
        }
    }
}

/// Gradient-bucket fusion (ROADMAP follow-up): coalescing consecutive
/// small-parameter layers into one reduce bucket changes delivery
/// batching only — exactly-associative payloads reduce **bit-equal**
/// across replica counts {1, 2, 4} and against the unbucketed reducer,
/// with the whole bucket delivered on the last contribution.
#[test]
fn bucketed_allreduce_bit_equal_one_vs_n_replicas() {
    let depth = 4usize;
    // All layers below the threshold -> buckets {0..=2} (threshold hit)
    // and the tail {3}.
    let layer_bytes = [48usize, 48, 48, 48];
    let global = |layer: usize| -> Vec<f32> {
        (0..6).map(|e| (layer * 48 + e * 2 + 4) as f32).collect()
    };
    let reduce_with = |replicas: usize, bucketed: bool| -> Vec<Vec<f32>> {
        let r = if bucketed {
            StreamingAllReduce::with_buckets(&layer_bytes, replicas, ReduceOp::Mean, 128)
        } else {
            StreamingAllReduce::new(depth, replicas, ReduceOp::Mean)
        };
        if bucketed {
            assert_eq!(r.bucket_count(), 2, "expected {{0,1,2}} and {{3}}");
        }
        let mut out: Vec<Option<Vec<f32>>> = vec![None; depth];
        for layer in 0..depth {
            for rep in 0..replicas {
                let t = Tensor::from_vec(global(layer), &[6]);
                for (li, g) in r.submit_bucketed(layer, rep, vec![t.clone()]) {
                    assert!(out[li].is_none(), "layer {li} delivered twice");
                    out[li] = Some(g[0].data().to_vec());
                }
            }
        }
        assert_eq!(r.reduced_layers(), depth);
        assert_eq!(r.pending_layers(), 0);
        out.into_iter().map(|o| o.expect("layer reduced")).collect()
    };
    let reference = reduce_with(1, false);
    for replicas in [1usize, 2, 4] {
        let fused = reduce_with(replicas, true);
        for (layer, (a, b)) in reference.iter().zip(&fused).enumerate() {
            assert_eq!(
                a, b,
                "layer {layer}: bucketed replicas={replicas} must be bit-equal"
            );
        }
    }
    // And the reduced payloads equal the exact global mean.
    for (layer, a) in reference.iter().enumerate() {
        assert_eq!(a, &global(layer));
    }
}

// ---------------------------------------------------------------------------
// 2. Gradient equivalence across the exact-engine grid
// ---------------------------------------------------------------------------

/// Shards of a global batch + per-shard mean losses, as the trainer
/// builds them.
fn shard_losses(labels: &[usize], replicas: usize) -> Vec<SoftmaxCrossEntropy> {
    let per = labels.len() / replicas;
    labels
        .chunks(per)
        .map(|c| SoftmaxCrossEntropy::new(c.to_vec()))
        .collect()
}

#[test]
fn replica_grads_match_single_replica_for_exact_engines() {
    let _pin = pin_lock();
    let net = tiny_cnn(0);
    let mut rng = Rng::new(1);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![0usize, 3, 1, 2];
    let full_loss = SoftmaxCrossEntropy::new(labels.clone());
    for name in EXACT_ENGINES {
        let engine = engine_by_name(name, 4, 2, 0).unwrap();
        let reference = pool::with_threads(4, || {
            let shards = [Shard {
                x: &x,
                loss: &full_loss,
            }];
            ReplicaGroup::new(1)
                .unwrap()
                .compute(&net, engine.as_ref(), &shards, ReduceOp::Mean)
                .unwrap()
        });
        for replicas in [2usize, 4] {
            let xs = split_batch(&x, replicas).unwrap();
            let losses = shard_losses(&labels, replicas);
            let shards: Vec<Shard<'_>> = xs
                .iter()
                .zip(&losses)
                .map(|(x, loss)| Shard {
                    x,
                    loss: loss as &dyn Loss,
                })
                .collect();
            let group = ReplicaGroup::new(replicas).unwrap();
            let got = pool::with_threads(4, || {
                group
                    .compute(&net, engine.as_ref(), &shards, ReduceOp::Mean)
                    .unwrap()
            });
            assert!(
                (got.loss - reference.loss).abs() <= 1e-5 * reference.loss.abs().max(1.0),
                "{name} r={replicas}: loss {} vs {}",
                got.loss,
                reference.loss
            );
            assert_eq!(got.replica_losses.len(), replicas);
            for (li, (a, b)) in reference.grads.iter().zip(&got.grads).enumerate() {
                assert_eq!(a.len(), b.len(), "{name} r={replicas}: arity at layer {li}");
                for (pi, (ga, gb)) in a.iter().zip(b).enumerate() {
                    let err = rel_err(gb, ga);
                    assert!(
                        err <= 1e-5,
                        "{name} r={replicas} layer {li} param {pi}: rel err {err} > 1e-5"
                    );
                }
            }
        }
    }
}

/// Fixed replica count + fixed thread count ⇒ bit-identical gradients
/// run-to-run, regardless of which worker executes which replica.
#[test]
fn replica_group_bit_identical_run_to_run() {
    let _pin = pin_lock();
    let net = tiny_cnn(2);
    let mut rng = Rng::new(3);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let engine = engine_by_name("moonwalk", 4, 0, 0).unwrap();
    for (replicas, threads) in [(2usize, 2usize), (2, 4), (4, 2)] {
        let xs = split_batch(&x, replicas).unwrap();
        let shards: Vec<Shard<'_>> = xs
            .iter()
            .map(|x| Shard {
                x,
                loss: &MeanLoss,
            })
            .collect();
        let group = ReplicaGroup::new(replicas).unwrap();
        let run = || {
            pool::with_threads(threads, || {
                group
                    .compute(&net, engine.as_ref(), &shards, ReduceOp::Mean)
                    .unwrap()
            })
        };
        let a = run();
        let b = run();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        for (la, lb) in a.grads.iter().zip(&b.grads) {
            for (ga, gb) in la.iter().zip(lb) {
                assert_eq!(
                    ga.data(),
                    gb.data(),
                    "r={replicas} t={threads}: grads must be bit-stable"
                );
            }
        }
    }
}

/// The streamed reduce must actually complete every parameterized layer
/// (sink called once per such layer, with replica-averaged payloads).
#[test]
fn streaming_sink_sees_every_parameterized_layer_once() {
    let _pin = pin_lock();
    let net = tiny_cnn(4);
    let mut rng = Rng::new(5);
    let x = Tensor::randn(&[2, 16, 16, 2], 1.0, &mut rng);
    let xs = split_batch(&x, 2).unwrap();
    let shards: Vec<Shard<'_>> = xs
        .iter()
        .map(|x| Shard {
            x,
            loss: &MeanLoss,
        })
        .collect();
    let group = ReplicaGroup::new(2).unwrap();
    let seen: Mutex<Vec<usize>> = Mutex::new(Vec::new());
    pool::with_threads(2, || {
        group
            .compute_streaming(&net, &Backprop, &shards, ReduceOp::Mean, &|li, g| {
                assert!(!g.is_empty(), "layer {li}: reduced grads must be non-empty");
                seen.lock().unwrap().push(li);
            })
            .unwrap()
    });
    let mut seen = seen.into_inner().unwrap();
    seen.sort();
    let expect: Vec<usize> = (0..net.depth())
        .filter(|&i| net.layers[i].n_params() > 0)
        .collect();
    assert_eq!(seen, expect, "each parameterized layer reduced exactly once");
}

// ---------------------------------------------------------------------------
// 3. Prefetch-pipeline determinism
// ---------------------------------------------------------------------------

#[test]
fn prefetch_pipeline_is_deterministic_and_replica_invariant() {
    let ds = TextureDataset::generate(
        SyntheticSpec {
            hw: 8,
            cin: 1,
            classes: 3,
            noise: 0.1,
            seed: 11,
        },
        20,
    );
    // Global sequence is invariant to the replica count...
    let seq = |replicas: usize| {
        let mut plan = BatchPlan::new(&ds, 4, replicas, 77).unwrap();
        (0..12)
            .map(|_| plan.next_step().global_indices)
            .collect::<Vec<_>>()
    };
    let base = seq(1);
    assert_eq!(base, seq(2));
    assert_eq!(base, seq(4));
    // ...and the async prefetcher streams the identical batches (twice,
    // to also catch cross-run nondeterminism).
    for _ in 0..2 {
        let prefetched: Vec<Vec<usize>> = std::thread::scope(|scope| {
            let plan = BatchPlan::new(&ds, 4, 2, 77).unwrap();
            let pf = Prefetcher::spawn(scope, plan, 12);
            (0..12)
                .map(|_| {
                    let (sb, _wait) = pf.next().unwrap();
                    // Shard payloads must agree with a direct materialize.
                    let per = sb.global_indices.len() / sb.raw_shards.len();
                    for (r, (pixels, labels)) in sb.raw_shards.iter().enumerate() {
                        let idx = &sb.global_indices[r * per..(r + 1) * per];
                        let (xr, lr) = ds.batch(idx);
                        assert_eq!(pixels.as_slice(), xr.data());
                        assert_eq!(labels, &lr);
                    }
                    sb.global_indices
                })
                .collect()
        });
        assert_eq!(base, prefetched);
    }
}

// ---------------------------------------------------------------------------
// 4. Failure handling
// ---------------------------------------------------------------------------

/// Panics in a designated replica (negative first input element).
struct PanicOnMarkedShard;

impl GradEngine for PanicOnMarkedShard {
    fn name(&self) -> String {
        "panic_on_marked_shard".into()
    }

    fn compute_streaming(
        &self,
        _net: &Network,
        x0: &Tensor,
        _loss: &dyn Loss,
        _sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        if x0.data()[0].is_sign_negative() {
            panic!("injected replica failure");
        }
        Ok(0.0)
    }
}

/// Errors (cleanly) in a designated replica.
struct ErrOnMarkedShard;

impl GradEngine for ErrOnMarkedShard {
    fn name(&self) -> String {
        "err_on_marked_shard".into()
    }

    fn compute_streaming(
        &self,
        _net: &Network,
        x0: &Tensor,
        _loss: &dyn Loss,
        _sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        anyhow::ensure!(!x0.data()[0].is_sign_negative(), "marked shard rejected");
        Ok(0.0)
    }
}

#[test]
fn panic_in_replica_reraises_and_pool_keeps_serving() {
    let _pin = pin_lock();
    let net = tiny_cnn(6);
    let mut rng = Rng::new(7);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let mut xs = split_batch(&x, 2).unwrap();
    for shard in xs.iter_mut() {
        shard.data_mut()[0] = 1.0; // unmark every shard deterministically
    }
    xs[1].data_mut()[0] = -1.0; // mark replica 1 as the panicker
    pool::with_threads(4, || {
        let shards: Vec<Shard<'_>> = xs
            .iter()
            .map(|x| Shard {
                x,
                loss: &MeanLoss,
            })
            .collect();
        let group = ReplicaGroup::new(2).unwrap();
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = group.compute(&net, &PanicOnMarkedShard, &shards, ReduceOp::Mean);
        }));
        assert!(boom.is_err(), "replica panic must re-raise on the caller");
        // The group (and the pool underneath) must keep serving: a
        // healthy step right after succeeds with correct results
        // (on unmarked shards re-split from the original batch).
        let clean = split_batch(&x, 2).unwrap();
        let clean_shards: Vec<Shard<'_>> = clean
            .iter()
            .map(|x| Shard {
                x,
                loss: &MeanLoss,
            })
            .collect();
        let reference = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let ok = group
            .compute(&net, &Backprop, &clean_shards, ReduceOp::Mean)
            .unwrap();
        assert!(
            (ok.loss - reference.loss).abs() <= 1e-5 * reference.loss.abs().max(1.0)
        );
        for (la, lb) in reference.grads.iter().zip(&ok.grads) {
            for (ga, gb) in la.iter().zip(lb) {
                assert!(rel_err(gb, ga) <= 1e-5, "post-panic grads must be correct");
            }
        }
    });
}

#[test]
fn error_in_replica_fails_step_with_replica_index() {
    let _pin = pin_lock();
    let net = tiny_cnn(8);
    let mut rng = Rng::new(9);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let mut xs = split_batch(&x, 4).unwrap();
    for shard in xs.iter_mut() {
        shard.data_mut()[0] = 1.0; // unmark every shard deterministically
    }
    xs[2].data_mut()[0] = -1.0; // mark replica 2
    pool::with_threads(2, || {
        let shards: Vec<Shard<'_>> = xs
            .iter()
            .map(|x| Shard {
                x,
                loss: &MeanLoss,
            })
            .collect();
        let group = ReplicaGroup::new(4).unwrap();
        let err = group
            .compute(&net, &ErrOnMarkedShard, &shards, ReduceOp::Mean)
            .expect_err("marked replica must fail the step");
        let msg = format!("{err:#}");
        assert!(msg.contains("replica 2"), "error should name the replica: {msg}");
    });
}
