//! Elastic fault-tolerance contract (ISSUE 6): the chaos grid.
//!
//! 1. **Kill recovery** — a worker kill -9'd mid-step (after it already
//!    streamed a gradient frame) under both socket families is detected,
//!    retried deterministically, and the run's loss curve is
//!    **bit-identical** to the no-fault run at equal replica count.
//! 2. **Hang recovery** — a worker that stops heartbeating and sleeps
//!    forever is declared dead after the heartbeat grace, then the step
//!    replays bit-identically (the failure mode a plain blocking read
//!    could never detect).
//! 3. **Frame faults** — dropped gradient frames trip the
//!    partial-delivery guard, corrupted frames fail with an error naming
//!    the replica and tag, delayed frames are harmless.
//! 4. **Exact-engine grid** — every engine in `EXACT_ENGINES` survives a
//!    mid-step kill and reproduces its pre-crash gradients bit-for-bit
//!    after respawn (worker engine state, including compiled plans, is
//!    rebuilt deterministically).
//! 5. **Elastic membership** — shrinking the executor set re-queues the
//!    fixed logical shards onto survivors bit-identically; growing back
//!    restores the original layout. Failover mode rides this to finish
//!    runs with a permanently dying worker.
//! 6. **Randomized chaos schedules** — pseudo-random fault plans (kill,
//!    hang, dropped and delayed frames) across `EXACT_ENGINES` × both
//!    socket families, each asserted bit-identical to its no-fault twin.
//!
//! Worker subprocesses are the real `moonwalk` binary
//! (`CARGO_BIN_EXE_moonwalk`) in its hidden `--replica-worker` mode.
//! Tests serialize through the same thread-pin mutex as the other
//! process-global suites.

use std::sync::Mutex;
use std::time::Duration;

use moonwalk::autodiff::{engine_by_name, EXACT_ENGINES};
use moonwalk::coordinator::{Optimizer, OptimizerKind, SyntheticSpec, TextureDataset, TrainReport, Trainer};
use moonwalk::distributed::transport::{
    Deadlines, EngineSpec, FaultPlan, LossSpec, ShardSpec, TcpTransport, TcpTransportOpts,
    Transport, UnixTransport, UnixTransportOpts,
};
use moonwalk::distributed::{split_batch, ReduceOp, RetryPolicy};
use moonwalk::model::config::Config;
use moonwalk::model::Network;
use moonwalk::tensor::Tensor;
use moonwalk::util::json::Json;
use moonwalk::util::Rng;

/// Serializes the tests that pin process-global state (pool threads,
/// subprocess load).
static THREAD_PIN: Mutex<()> = Mutex::new(());

fn pin_lock() -> std::sync::MutexGuard<'static, ()> {
    match THREAD_PIN.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// The tiny CNN of the transport suite, as a `Config` so worker
/// subprocesses rebuild the identical architecture.
fn tiny_cfg(seed: u64) -> Config {
    Config::from_json(
        &Json::parse(&format!(
            r#"{{"arch": "cnn2d", "depth": 2, "channels": 5, "input_hw": 16,
                 "cin": 2, "classes": 4, "alpha": 0.1, "constrained": true,
                 "seed": {seed}}}"#
        ))
        .unwrap(),
    )
    .unwrap()
}

fn tiny_net(cfg: &Config) -> Network {
    let mut rng = Rng::new(cfg.seed);
    cfg.build_network(&mut rng)
}

/// Short supervision deadlines so fault detection is fast: a 50 ms
/// heartbeat puts the hang grace at its 500 ms floor, and the 60 s step
/// deadline stays a backstop that never fires in a healthy test.
fn fast_deadlines() -> Deadlines {
    Deadlines {
        accept: Duration::from_secs(30),
        hello: Duration::from_secs(10),
        step: Some(Duration::from_secs(60)),
        heartbeat_ms: 50,
    }
}

/// The two socket families the chaos grid runs over.
#[derive(Clone, Copy, Debug)]
enum Family {
    Unix,
    Tcp,
}

const FAMILIES: [Family; 2] = [Family::Unix, Family::Tcp];

impl Family {
    fn label(self) -> &'static str {
        match self {
            Family::Unix => "unix",
            Family::Tcp => "tcp",
        }
    }
}

/// Spawn a 2-worker transport of `family` with an explicit fault plan.
fn spawn_family(
    family: Family,
    cfg: &Config,
    engine: EngineSpec,
    replicas: usize,
    deadlines: Deadlines,
    faults: FaultPlan,
) -> Box<dyn Transport> {
    let bin = Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_moonwalk")));
    match family {
        Family::Unix => {
            let mut opts = UnixTransportOpts::new(replicas, cfg.to_json().to_string(), engine);
            opts.worker_bin = bin;
            opts.deadlines = deadlines;
            opts.faults = faults;
            Box::new(UnixTransport::spawn(opts).expect("spawn unix transport"))
        }
        Family::Tcp => {
            let mut opts = TcpTransportOpts::new(replicas, cfg.to_json().to_string(), engine);
            opts.worker_bin = bin;
            opts.deadlines = deadlines;
            opts.faults = faults;
            Box::new(TcpTransport::spawn(opts).expect("spawn tcp transport"))
        }
    }
}

/// The worker-side spelling of the trainer's engine configuration.
fn engine_spec(cfg: &Config, name: &str) -> EngineSpec {
    EngineSpec {
        name: name.to_string(),
        block: cfg.block,
        checkpoint_segments: cfg.checkpoint_every,
        seed: cfg.seed,
    }
}

/// One full trainer run (replicas = 2, batch 4) over `family` with the
/// given fault spec — the no-fault twin passes `""`.
fn train_run(
    cfg: &Config,
    engine_name: &str,
    family: Family,
    fault_spec: &str,
    retry: RetryPolicy,
    steps: usize,
) -> TrainReport {
    let data = TextureDataset::generate(
        SyntheticSpec {
            hw: 16,
            cin: 2,
            classes: 4,
            noise: 0.15,
            seed: cfg.seed + 100,
        },
        40,
    );
    let (train, test) = data.split(0.2);
    let mut net = tiny_net(cfg);
    let engine = engine_by_name(engine_name, cfg.block, cfg.checkpoint_every, cfg.seed).unwrap();
    let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
    let faults = FaultPlan::parse(fault_spec).unwrap();
    let transport = spawn_family(
        family,
        cfg,
        engine_spec(cfg, engine_name),
        2,
        fast_deadlines(),
        faults,
    );
    let mut trainer = Trainer::new(&mut net, engine.as_ref(), opt);
    trainer.replicas = 2;
    trainer.retry = retry;
    trainer.transport = Some(transport);
    let mut rng = Rng::new(cfg.seed + 7);
    trainer
        .train(&train, &test, 4, steps, &mut rng, None)
        .unwrap()
}

fn assert_curves_bit_identical(label: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{label}: loss curve length");
    for (step, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{label} step {step}: no-fault {x} vs faulted {y}"
        );
    }
}

/// One collected step through any transport (transport-suite idiom).
fn step_collect(
    t: &mut dyn Transport,
    net: &Network,
    engine: &dyn moonwalk::autodiff::GradEngine,
    xs: &[Tensor],
    labels: &[usize],
) -> anyhow::Result<(f32, Vec<Vec<Tensor>>)> {
    let per = labels.len() / xs.len();
    let shards: Vec<ShardSpec<'_>> = xs
        .iter()
        .enumerate()
        .map(|(r, x)| ShardSpec {
            x,
            loss: LossSpec::SoftmaxXent(&labels[r * per..(r + 1) * per]),
        })
        .collect();
    let grads: Mutex<Vec<Vec<Tensor>>> =
        Mutex::new((0..net.depth()).map(|_| Vec::new()).collect());
    let step = t.step(net, engine, &shards, ReduceOp::Mean, &|li, g| {
        grads.lock().unwrap()[li] = g;
    })?;
    Ok((step.loss, grads.into_inner().unwrap()))
}

fn assert_grads_bit_identical(label: &str, a: &[Vec<Tensor>], b: &[Vec<Tensor>]) {
    assert_eq!(a.len(), b.len(), "{label}: layer count");
    for (li, (la, lb)) in a.iter().zip(b).enumerate() {
        assert_eq!(la.len(), lb.len(), "{label} layer {li}: gradient arity");
        for (pi, (ga, gb)) in la.iter().zip(lb).enumerate() {
            assert_eq!(ga.shape(), gb.shape(), "{label} layer {li} param {pi}");
            for (va, vb) in ga.data().iter().zip(gb.data()) {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "{label} layer {li} param {pi}: gradient bits"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 1. Kill recovery — the acceptance test
// ---------------------------------------------------------------------------

/// A worker kill -9'd mid-step (it aborts right after flushing its first
/// gradient frame, leaving the coordinator holding a partial delivery)
/// under **both** socket families: the run completes with a loss curve
/// bit-identical to the no-fault run at the same replica count, and the
/// report records the retry.
#[test]
fn kill_mid_step_recovers_bit_identical_loss_curve() {
    let _pin = pin_lock();
    let retry = RetryPolicy {
        retries: 2,
        backoff_ms: 5,
        failover: false,
    };
    for family in FAMILIES {
        for engine in ["backprop", "moonwalk"] {
            let cfg = tiny_cfg(20);
            let clean = train_run(&cfg, engine, family, "", retry, 3);
            let faulted = train_run(&cfg, engine, family, "kill:1@1", retry, 3);
            let label = format!("{}/{engine} kill:1@1", family.label());
            assert_curves_bit_identical(&label, &clean.loss_curve, &faulted.loss_curve);
            assert!(faulted.retries >= 1, "{label}: retry must be recorded");
            assert_eq!(faulted.failovers, 0, "{label}: no failover expected");
            assert_eq!(clean.retries, 0, "{label}: clean run must not retry");
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Hang recovery
// ---------------------------------------------------------------------------

/// A worker that stops heartbeating and sleeps forever mid-step is
/// declared dead after the heartbeat grace and the step replays
/// bit-identically — on both families.
#[test]
fn hung_worker_detected_and_recovered_bit_identical() {
    let _pin = pin_lock();
    let retry = RetryPolicy {
        retries: 2,
        backoff_ms: 5,
        failover: false,
    };
    for family in FAMILIES {
        let cfg = tiny_cfg(21);
        let clean = train_run(&cfg, "backprop", family, "", retry, 3);
        let faulted = train_run(&cfg, "backprop", family, "hang:0@1", retry, 3);
        let label = format!("{} hang:0@1", family.label());
        assert_curves_bit_identical(&label, &clean.loss_curve, &faulted.loss_curve);
        assert!(faulted.retries >= 1, "{label}: retry must be recorded");
    }
}

/// The step-level hang error blames the heartbeat grace, naming the
/// silent replica — the observable difference from a plain dead socket.
#[test]
fn hang_error_names_heartbeat_grace() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(22);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(1);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![0usize, 3, 1, 2];
    let xs = split_batch(&x, 2).unwrap();
    let engine = engine_by_name("backprop", 4, 0, 0).unwrap();
    let mut t = spawn_family(
        Family::Unix,
        &cfg,
        EngineSpec::new("backprop"),
        2,
        fast_deadlines(),
        FaultPlan::parse("hang:0@0").unwrap(),
    );
    t.broadcast(&net).unwrap();
    let err = step_collect(t.as_mut(), &net, engine.as_ref(), &xs, &labels)
        .expect_err("a hung worker must fail the step");
    let msg = format!("{err:#}");
    assert!(msg.contains("presumed hung"), "hang diagnosis: {msg}");
    assert!(msg.contains("replica 0"), "hang error names replica: {msg}");
}

// ---------------------------------------------------------------------------
// 3. Frame faults
// ---------------------------------------------------------------------------

/// A dropped gradient frame trips the partial-delivery guard (the step
/// fails rather than silently reducing a short fold), and after a
/// rebroadcast the group reproduces the clean gradients bit-for-bit.
#[test]
fn dropped_frame_trips_partial_delivery_guard() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(23);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(2);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![1usize, 2, 0, 3];
    let xs = split_batch(&x, 2).unwrap();
    let engine = engine_by_name("backprop", 4, 0, 0).unwrap();
    let mut clean = spawn_family(
        Family::Unix,
        &cfg,
        EngineSpec::new("backprop"),
        2,
        fast_deadlines(),
        FaultPlan::default(),
    );
    clean.broadcast(&net).unwrap();
    let (ref_loss, ref_grads) =
        step_collect(clean.as_mut(), &net, engine.as_ref(), &xs, &labels).unwrap();

    let mut faulted = spawn_family(
        Family::Unix,
        &cfg,
        EngineSpec::new("backprop"),
        2,
        fast_deadlines(),
        FaultPlan::parse("drop:0@0").unwrap(),
    );
    faulted.broadcast(&net).unwrap();
    let err = step_collect(faulted.as_mut(), &net, engine.as_ref(), &xs, &labels)
        .expect_err("a dropped gradient frame must fail the step");
    let msg = format!("{err:#}");
    assert!(msg.contains("never finished"), "partial-delivery guard: {msg}");

    faulted.broadcast(&net).unwrap();
    let (loss, grads) =
        step_collect(faulted.as_mut(), &net, engine.as_ref(), &xs, &labels).unwrap();
    assert_eq!(loss.to_bits(), ref_loss.to_bits(), "post-drop recovery loss");
    assert_grads_bit_identical("drop recovery", &ref_grads, &grads);
}

/// A corrupted frame tag fails with an error naming the replica, the
/// family and the bogus tag byte — the supervision layer's attribution
/// contract.
#[test]
fn corrupt_frame_error_names_replica_and_tag() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(24);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(3);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![0usize, 1, 2, 3];
    let xs = split_batch(&x, 2).unwrap();
    let engine = engine_by_name("backprop", 4, 0, 0).unwrap();
    let mut t = spawn_family(
        Family::Unix,
        &cfg,
        EngineSpec::new("backprop"),
        2,
        fast_deadlines(),
        FaultPlan::parse("corrupt:0@0").unwrap(),
    );
    t.broadcast(&net).unwrap();
    let err = step_collect(t.as_mut(), &net, engine.as_ref(), &xs, &labels)
        .expect_err("a corrupt frame must fail the step");
    let msg = format!("{err:#}");
    assert!(msg.contains("corrupt frame tag"), "decode diagnosis: {msg}");
    assert!(msg.contains("replica 0"), "corrupt error names replica: {msg}");
    // Recovery path: rebroadcast, then the next step serves cleanly.
    t.broadcast(&net).unwrap();
    step_collect(t.as_mut(), &net, engine.as_ref(), &xs, &labels)
        .expect("group must serve after recovering from a corrupt frame");
}

/// A delayed gradient frame (transient slow link shorter than the
/// heartbeat grace) is harmless: the step succeeds with gradients
/// bit-identical to an undelayed run.
#[test]
fn delayed_frame_is_bit_identical() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(25);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(4);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![3usize, 0, 2, 1];
    let xs = split_batch(&x, 2).unwrap();
    let engine = engine_by_name("backprop", 4, 0, 0).unwrap();
    let mut clean = spawn_family(
        Family::Unix,
        &cfg,
        EngineSpec::new("backprop"),
        2,
        fast_deadlines(),
        FaultPlan::default(),
    );
    clean.broadcast(&net).unwrap();
    let (ref_loss, ref_grads) =
        step_collect(clean.as_mut(), &net, engine.as_ref(), &xs, &labels).unwrap();
    let mut delayed = spawn_family(
        Family::Unix,
        &cfg,
        EngineSpec::new("backprop"),
        2,
        fast_deadlines(),
        FaultPlan::parse("delay40:1@0").unwrap(),
    );
    delayed.broadcast(&net).unwrap();
    let (loss, grads) =
        step_collect(delayed.as_mut(), &net, engine.as_ref(), &xs, &labels).unwrap();
    assert_eq!(loss.to_bits(), ref_loss.to_bits(), "delayed-frame loss");
    assert_grads_bit_identical("delay40", &ref_grads, &grads);
}

// ---------------------------------------------------------------------------
// 4. Exact-engine kill grid
// ---------------------------------------------------------------------------

/// Every exact engine survives a mid-step kill: the failed step names
/// the dead replica, the rebroadcast respawns it (rebuilding the engine
/// — including any compiled execution plan — deterministically), and
/// the replayed step reproduces the pre-crash gradients bit-for-bit.
#[test]
fn exact_engine_grid_kill_recovery_bit_exact() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(26);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(5);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![2usize, 1, 3, 0];
    let xs = split_batch(&x, 2).unwrap();
    for name in EXACT_ENGINES {
        let engine = engine_by_name(name, 4, 2, 0).unwrap();
        let spec = EngineSpec {
            name: name.to_string(),
            block: 4,
            checkpoint_segments: 2,
            seed: 0,
        };
        let mut t = spawn_family(
            Family::Unix,
            &cfg,
            spec,
            2,
            fast_deadlines(),
            FaultPlan::parse("kill:1@1").unwrap(),
        );
        t.broadcast(&net).unwrap();
        let (loss0, grads0) =
            step_collect(t.as_mut(), &net, engine.as_ref(), &xs, &labels).unwrap();
        let err = step_collect(t.as_mut(), &net, engine.as_ref(), &xs, &labels)
            .expect_err("the armed kill must fail the second step");
        assert!(
            format!("{err:#}").contains("replica 1"),
            "{name}: kill error names the replica: {err:#}"
        );
        t.broadcast(&net).unwrap();
        let (loss1, grads1) =
            step_collect(t.as_mut(), &net, engine.as_ref(), &xs, &labels).unwrap();
        assert_eq!(loss1.to_bits(), loss0.to_bits(), "{name}: replayed loss");
        assert_grads_bit_identical(name, &grads0, &grads1);
    }
}

// ---------------------------------------------------------------------------
// 5. Elastic membership
// ---------------------------------------------------------------------------

/// Shrinking the executor set re-queues the fixed logical shards onto
/// the survivors bit-identically (the reducer folds in logical shard
/// order, not delivery order); growing back restores the original
/// layout, still bit-identical.
#[test]
fn elastic_membership_shrink_and_grow_bit_identical() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(27);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(6);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![1usize, 0, 3, 2];
    let xs = split_batch(&x, 2).unwrap();
    let engine = engine_by_name("moonwalk", 4, 2, 0).unwrap();
    let mut t = spawn_family(
        Family::Unix,
        &cfg,
        EngineSpec::new("moonwalk"),
        2,
        fast_deadlines(),
        FaultPlan::default(),
    );
    t.broadcast(&net).unwrap();
    assert_eq!(t.members(), 2);
    let (loss_full, grads_full) =
        step_collect(t.as_mut(), &net, engine.as_ref(), &xs, &labels).unwrap();

    t.set_members(1).unwrap();
    t.broadcast(&net).unwrap();
    assert_eq!(t.members(), 1);
    let (loss_one, grads_one) =
        step_collect(t.as_mut(), &net, engine.as_ref(), &xs, &labels).unwrap();
    assert_eq!(
        loss_one.to_bits(),
        loss_full.to_bits(),
        "1-member loss must match the 2-member fold"
    );
    assert_grads_bit_identical("members=1", &grads_full, &grads_one);

    t.set_members(2).unwrap();
    t.broadcast(&net).unwrap();
    assert_eq!(t.members(), 2);
    let (loss_back, grads_back) =
        step_collect(t.as_mut(), &net, engine.as_ref(), &xs, &labels).unwrap();
    assert_eq!(loss_back.to_bits(), loss_full.to_bits(), "regrown loss");
    assert_grads_bit_identical("regrown members=2", &grads_full, &grads_back);
}

/// Failover mode finishes a run whose replica 1 dies on **every** step
/// it serves (`kill:1@*` re-arms after each respawn — a permanently
/// failing host): the group shrinks to the survivor and the loss curve
/// stays bit-identical to the healthy 2-member run.
#[test]
fn failover_completes_run_with_permanently_dying_worker() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(28);
    let clean = train_run(
        &cfg,
        "backprop",
        Family::Unix,
        "",
        RetryPolicy {
            retries: 1,
            backoff_ms: 5,
            failover: true,
        },
        3,
    );
    let faulted = train_run(
        &cfg,
        "backprop",
        Family::Unix,
        "kill:1@*",
        RetryPolicy {
            retries: 1,
            backoff_ms: 5,
            failover: true,
        },
        3,
    );
    assert_curves_bit_identical("failover kill:1@*", &clean.loss_curve, &faulted.loss_curve);
    assert!(faulted.failovers >= 1, "the shrink must be recorded");
    assert_eq!(clean.failovers, 0, "clean run must not fail over");
}

// ---------------------------------------------------------------------------
// 6. Randomized chaos schedules
// ---------------------------------------------------------------------------

/// The chaos grid: for every exact engine × both socket families, a
/// deterministic pseudo-random fault schedule (1–2 faults drawn from
/// kill / dropped frame / delayed frame / hang, random replica and
/// step) is injected into a short training run, which must stay
/// bit-identical to its no-fault twin at the same replica count.
#[test]
fn chaos_schedules_bit_identical_across_engines_and_transports() {
    let _pin = pin_lock();
    let retry = RetryPolicy {
        retries: 3,
        backoff_ms: 5,
        failover: false,
    };
    for (ei, engine) in EXACT_ENGINES.iter().enumerate() {
        for family in FAMILIES {
            // Deterministic per-combo schedule; hangs are rare (1 in 8)
            // because each costs a 500 ms detection grace.
            let mut rng = Rng::new(1000 + ei as u64 * 2 + family.label().len() as u64);
            let n_faults = 1 + rng.below(2);
            let spec = (0..n_faults)
                .map(|_| {
                    let kind = match rng.below(8) {
                        0..=2 => "kill".to_string(),
                        3 | 4 => "drop".to_string(),
                        5 | 6 => "delay40".to_string(),
                        _ => "hang".to_string(),
                    };
                    format!("{kind}:{}@{}", rng.below(2), rng.below(2))
                })
                .collect::<Vec<_>>()
                .join(",");
            let cfg = tiny_cfg(30 + ei as u64);
            let clean = train_run(&cfg, engine, family, "", retry, 2);
            let faulted = train_run(&cfg, engine, family, &spec, retry, 2);
            let label = format!("{}/{engine} chaos [{spec}]", family.label());
            assert_curves_bit_identical(&label, &clean.loss_curve, &faulted.loss_curve);
            assert_eq!(faulted.failovers, 0, "{label}: retries must suffice");
        }
    }
}
