//! The reversible layer family, end to end: finite-difference gradchecks
//! for every `nn/` layer, cross-engine equivalence on 100+-layer block
//! stacks, depth grids proving the zero-residual memory contract (peak
//! flat in depth for Moonwalk/planned, linear for Backprop), the planner
//! discovering free vijps unaided, indexed layer errors, and the
//! parameter wire format on block topologies.

mod common;

use std::io::Cursor;
use std::sync::Mutex;

use common::gradcheck::{self, gradcheck_layer};
use moonwalk::autodiff::{
    engine_by_name, Backprop, GradEngine, Moonwalk, MoonwalkOpts, PlannedEngine, RevBackprop,
    EXACT_ENGINES,
};
use moonwalk::coordinator::sweep::measure_engine;
use moonwalk::distributed::transport::wire;
use moonwalk::model::{build_revnet, Network, RevNetSpec, RevNetVariant};
use moonwalk::nn::{
    Conv1d, Conv2d, CouplingBlock, Dense, Layer, LayerError, LeakyRelu, MaxPool2d, MeanLoss,
    MomentumBlock, Residual, ResidualBlock, ResidualData, ResidualKind, Submersivity, Upsample,
    residual_bytes,
};
use moonwalk::plan::{build_frontier, probe_network, Strategy, DEFAULT_FRAG_BLOCKS};
use moonwalk::runtime::pool;
use moonwalk::tensor::{rel_err, Tensor};
use moonwalk::util::Rng;

/// Serializes the tests that pin the (process-global) pool thread count
/// or compare tracked peaks (the tracker is process-global too).
static THREAD_PIN: Mutex<()> = Mutex::new(());

fn pin_lock() -> std::sync::MutexGuard<'static, ()> {
    match THREAD_PIN.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// The acceptance bar for every finite-difference check in this suite.
const GRADCHECK_TOL: f32 = 1e-3;

/// Random input with every element pushed at least 0.25 from zero, so a
/// ±`FD_EPS` probe cannot cross a LeakyReLU kink and corrupt the
/// central-difference estimate.
fn margin_input(shape: &[usize], rng: &mut Rng) -> Tensor {
    let mut x = Tensor::randn(shape, 1.0, rng);
    for v in x.data_mut() {
        if v.abs() < 0.25 {
            *v += if *v < 0.0 { -0.25 } else { 0.25 };
        }
    }
    x
}

/// Deterministic input whose values are separated by ≥ 0.3, so a
/// ±`FD_EPS` probe cannot flip a pooling argmax mid-check.
fn grid_input(shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n).map(|i| (i * 37 % 97) as f32 * 0.3).collect();
    Tensor::from_vec(data, shape)
}

// ---------------------------------------------------------------------------
// Gradcheck: every layer family against central differences.
// ---------------------------------------------------------------------------

#[test]
fn gradcheck_every_layer_family() {
    let mut rng = Rng::new(11);
    // (layer, input) pairs covering every family in `nn/`.
    let mut cases: Vec<(Box<dyn Layer>, Tensor)> = vec![
        (
            Box::new(Dense::new(6, 4, true, &mut rng)),
            Tensor::randn(&[3, 6], 1.0, &mut rng),
        ),
        (
            Box::new(LeakyRelu::new(0.1)),
            margin_input(&[2, 5, 3], &mut rng),
        ),
        (
            Box::new(Conv1d::new_submersive(3, 2, 2, 2, 1, &mut rng)),
            Tensor::randn(&[2, 8, 2], 1.0, &mut rng),
        ),
        (
            Box::new(Conv1d::new_fragmental(3, 2, 3, &mut rng)),
            Tensor::randn(&[2, 8, 2], 1.0, &mut rng),
        ),
        (
            Box::new(Conv2d::new_submersive(3, 2, 3, 2, 1, true, &mut rng)),
            Tensor::randn(&[1, 8, 8, 2], 1.0, &mut rng),
        ),
        (Box::new(MaxPool2d::new(2)), grid_input(&[1, 4, 4, 2])),
        (Box::new(Upsample::new(2, 4)), Tensor::randn(&[1, 4, 4, 2], 1.0, &mut rng)),
        (
            Box::new(ResidualBlock::new(Box::new(Dense::new(2, 2, true, &mut rng)))),
            Tensor::randn(&[3, 4], 1.0, &mut rng),
        ),
        (
            // Nonlinear inner: its input is the block's first channel
            // half verbatim, so the margin conditioning still protects
            // the finite differences from the kink.
            Box::new(ResidualBlock::new(Box::new(LeakyRelu::new(0.2)))),
            margin_input(&[3, 4], &mut rng),
        ),
        (
            Box::new(CouplingBlock::new(
                Box::new(Dense::new(2, 2, true, &mut rng)),
                Box::new(Dense::new(2, 2, false, &mut rng)),
            )),
            Tensor::randn(&[3, 4], 1.0, &mut rng),
        ),
        (
            Box::new(MomentumBlock::new(Box::new(Dense::new(3, 3, true, &mut rng)), 0.9)),
            Tensor::randn(&[2, 6], 1.0, &mut rng),
        ),
    ];
    for (seed, (layer, x)) in cases.iter_mut().enumerate() {
        gradcheck_layer(layer.as_mut(), x, 100 + seed as u64, GRADCHECK_TOL);
    }
}

#[test]
fn vijp_roundtrip_survives_nonlinear_coupling() {
    // The analytic (FD-free) round-trip also holds with a nonlinear
    // branch whose kinks the FD battery above must avoid.
    let mut rng = Rng::new(12);
    let block = CouplingBlock::new(
        Box::new(Dense::new(3, 3, true, &mut rng)),
        Box::new(LeakyRelu::new(0.3)),
    );
    let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
    gradcheck::check_vijp_roundtrip(&block, &x, 77, GRADCHECK_TOL);
}

// ---------------------------------------------------------------------------
// Zero-residual contract.
// ---------------------------------------------------------------------------

#[test]
fn revnet_stacks_store_zero_minimal_residual_bytes() {
    let mut rng = Rng::new(21);
    for variant in [
        RevNetVariant::Coupling,
        RevNetVariant::Momentum,
        RevNetVariant::Residual,
        RevNetVariant::Mixed,
    ] {
        let net = build_revnet(
            &RevNetSpec { channels: 8, depth: 9, variant, ..Default::default() },
            &mut rng,
        );
        let mut x = Tensor::randn(&[2, 8], 1.0, &mut rng);
        for layer in &net.layers {
            let (y, res) = layer.forward_res(&x, ResidualKind::Minimal);
            assert_eq!(
                residual_bytes(&res),
                0,
                "{}: Minimal residual must be empty",
                layer.name()
            );
            assert!(matches!(
                res.kind,
                ResidualData::Block { input: None, .. }
            ));
            x = y;
        }
    }
}

#[test]
fn blocks_are_submersive_even_with_nonsubmersive_branches() {
    // The coupling structure lifts *any* branch into a submersive
    // composite: a stride-1/pad-1 conv is NOT submersive on its own
    // (s ≤ p breaks the Lemma-1 elimination), yet a coupling block built
    // from two of them is — the composite Jacobian is unit-triangular.
    let mut rng = Rng::new(22);
    let branch = |rng: &mut Rng| Box::new(Conv1d::new_fragmental(3, 1, 1, rng));
    assert!(
        !branch(&mut rng).submersivity().is_submersive(),
        "the branch itself must be non-submersive for this test to bite"
    );
    let mut block = CouplingBlock::new(branch(&mut rng), branch(&mut rng));
    assert_eq!(
        block.submersivity(),
        Submersivity::Submersive { fast_path: true }
    );
    // And the lifted quartet is numerically correct end to end.
    let mut x_rng = Rng::new(23);
    let x = Tensor::randn(&[2, 8, 2], 1.0, &mut x_rng);
    gradcheck_layer(&mut block, &x, 230, GRADCHECK_TOL);
}

// ---------------------------------------------------------------------------
// Cross-engine equivalence on block stacks.
// ---------------------------------------------------------------------------

fn assert_engines_match(net: &Network, x: &Tensor, engines: &[Box<dyn GradEngine>], tol: f32) {
    let reference = Backprop.compute(net, x, &MeanLoss).unwrap();
    for engine in engines {
        let got = engine
            .compute(net, x, &MeanLoss)
            .unwrap_or_else(|e| panic!("{} failed: {e}", engine.name()));
        assert!(
            (got.loss - reference.loss).abs() <= 1e-5 * reference.loss.abs().max(1.0),
            "{}: loss {} vs {}",
            engine.name(),
            got.loss,
            reference.loss
        );
        for (li, (a, b)) in reference.grads.iter().zip(&got.grads).enumerate() {
            assert_eq!(a.len(), b.len(), "{}: arity at layer {li}", engine.name());
            for (pi, (ga, gb)) in a.iter().zip(b).enumerate() {
                let err = rel_err(gb, ga);
                assert!(
                    err <= tol,
                    "{} layer {li} param {pi}: rel err {err} > {tol}",
                    engine.name()
                );
            }
        }
    }
}

fn exact_engines() -> Vec<Box<dyn GradEngine>> {
    EXACT_ENGINES
        .iter()
        .map(|n| engine_by_name(n, 8, 0, 0).unwrap())
        .collect()
}

#[test]
fn all_exact_engines_agree_on_every_block_variant() {
    let _pin = pin_lock();
    for variant in [
        RevNetVariant::Coupling,
        RevNetVariant::Momentum,
        RevNetVariant::Residual,
        RevNetVariant::Mixed,
    ] {
        let mut rng = Rng::new(31);
        let net = build_revnet(
            &RevNetSpec { channels: 8, depth: 6, variant, ..Default::default() },
            &mut rng,
        );
        let x = Tensor::randn(&[4, 8], 1.0, &mut rng);
        for threads in [1usize, 4] {
            pool::with_threads(threads, || {
                assert_engines_match(&net, &x, &exact_engines(), 5e-3);
            });
        }
    }
}

// ---------------------------------------------------------------------------
// 100+-layer depth: training end to end, and the memory story.
// ---------------------------------------------------------------------------

fn deep_coupling_net(depth: usize) -> (Network, Tensor) {
    let mut rng = Rng::new(42);
    let net = build_revnet(
        &RevNetSpec {
            channels: 8,
            depth,
            variant: RevNetVariant::Coupling,
            ..Default::default()
        },
        &mut rng,
    );
    let x = Tensor::randn(&[4, 8], 1.0, &mut rng);
    (net, x)
}

/// Train `steps` of plain SGD with `engine` on a fresh 128-layer
/// coupling stack (identical init every call) and return the loss curve.
fn train_curve(engine: &dyn GradEngine, steps: usize, lr: f32) -> Vec<f32> {
    let (mut net, x) = deep_coupling_net(128);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let got = engine.compute(&net, &x, &MeanLoss).unwrap();
        losses.push(got.loss);
        for (layer, grads) in net.layers.iter_mut().zip(&got.grads) {
            for (p, g) in layer.params_mut().into_iter().zip(grads) {
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data()) {
                    *pv -= lr * gv;
                }
            }
        }
    }
    losses
}

#[test]
fn deep_128_layer_stack_trains_identically_across_engines() {
    let _pin = pin_lock();
    pool::with_threads(1, || {
        let reference = train_curve(&Backprop, 4, 0.05);
        assert!(
            reference.last().unwrap() < reference.first().unwrap(),
            "SGD on the 128-layer stack must reduce the loss: {reference:?}"
        );
        for name in EXACT_ENGINES {
            let engine = engine_by_name(name, 8, 0, 0).unwrap();
            let curve = train_curve(engine.as_ref(), 4, 0.05);
            for (step, (a, b)) in reference.iter().zip(&curve).enumerate() {
                let gap = (a - b).abs() / a.abs().max(1.0);
                assert!(
                    gap <= 1e-3,
                    "{name}: loss curve diverged at step {step}: {a} vs {b}"
                );
            }
        }
    });
}

#[test]
fn planned_unbounded_is_bit_identical_to_backprop_on_deep_stack() {
    let _pin = pin_lock();
    pool::with_threads(1, || {
        let (net, x) = deep_coupling_net(128);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let planned = PlannedEngine::with_budget(None);
        let pl = planned.compute(&net, &x, &MeanLoss).unwrap();
        assert_eq!(bp.loss.to_bits(), pl.loss.to_bits(), "loss must be bit-identical");
        for (a, b) in bp.grads.iter().flatten().zip(pl.grads.iter().flatten()) {
            assert_eq!(a.shape(), b.shape());
            for (va, vb) in a.data().iter().zip(b.data()) {
                assert_eq!(va.to_bits(), vb.to_bits(), "grads must be bit-identical");
            }
        }
    });
}

/// Tracked peak bytes for one engine across a coupling-depth grid.
fn depth_grid_peaks(mk: &dyn Fn(&Network, &Tensor) -> Box<dyn GradEngine>, depths: &[usize]) -> Vec<usize> {
    depths
        .iter()
        .map(|&depth| {
            let (net, x) = deep_coupling_net(depth);
            let engine = mk(&net, &x);
            let (peak, _, _) =
                measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 1, 1).unwrap();
            peak
        })
        .collect()
}

#[test]
fn depth_grid_peak_flat_for_moonwalk_and_planned_linear_for_backprop() {
    let _pin = pin_lock();
    let depths = [8usize, 32, 128];
    pool::with_threads(1, || {
        let bp = depth_grid_peaks(&|_, _| Box::new(Backprop), &depths);
        let mw = depth_grid_peaks(
            &|_, _| Box::new(Moonwalk::new(MoonwalkOpts::default())),
            &depths,
        );
        let pl = depth_grid_peaks(
            &|net, x| {
                // Tightest feasible budget — forces the all-vijp plan.
                let probes = probe_network(net, x.shape(), DEFAULT_FRAG_BLOCKS).unwrap();
                let budget = build_frontier(&probes).min_peak();
                Box::new(PlannedEngine::with_budget(Some(budget)))
            },
            &depths,
        );
        // Backprop's tape stores each block's Full residual (the block
        // input: 4×8 f32 = 128 bytes per layer), so 8 → 128 layers must
        // add at least 120 × 128 bytes to the peak.
        assert!(
            bp[2] >= bp[0] + 120 * 128,
            "backprop peak must grow linearly in depth: {bp:?}"
        );
        // Moonwalk and the planned engine store no per-layer residuals
        // on a coupling stack: peak stays flat from depth 8 to 128.
        for (name, peaks) in [("moonwalk", &mw), ("planned", &pl)] {
            assert!(
                (peaks[2] as f64) < (peaks[0] as f64) * 1.5 + 2048.0,
                "{name} peak must be flat in depth: {peaks:?} (backprop: {bp:?})"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Planner: the free vijp is discovered, not hinted.
// ---------------------------------------------------------------------------

#[test]
fn planner_assigns_vijp_to_every_reversible_layer_at_tight_budget() {
    let (net, x) = deep_coupling_net(16);
    let probes = probe_network(&net, x.shape(), DEFAULT_FRAG_BLOCKS).unwrap();
    for p in &probes {
        assert!(p.cost.submersive, "{}: block must probe submersive", p.cost.name);
        assert!(p.cost.fast_vijp, "{}: block vijp has no wavefront", p.cost.name);
        assert_eq!(p.measured_mx, 0, "{}: zero Minimal residual", p.cost.name);
    }
    let frontier = build_frontier(&probes);
    let plan = frontier.select(&probes, Some(frontier.min_peak())).unwrap();
    for (i, d) in plan.decisions.iter().enumerate() {
        assert_eq!(
            d.strategy,
            Strategy::Vijp,
            "layer {i} ({}) should ride the free vijp",
            probes[i].cost.name
        );
        assert_eq!(d.aid_bytes, 0, "vijp stores nothing");
    }
}

// ---------------------------------------------------------------------------
// Layer errors carry the layer index and name.
// ---------------------------------------------------------------------------

/// A layer that *claims* submersivity but whose vijp always fails —
/// the engines must surface the failure with the layer's index.
struct LyingLayer;

impl Layer for LyingLayer {
    fn name(&self) -> String {
        "liar".into()
    }
    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        Ok(in_shape.to_vec())
    }
    fn forward_res(&self, x: &Tensor, _kind: ResidualKind) -> (Tensor, Residual) {
        (
            x.clone(),
            Residual { in_shape: x.shape().to_vec(), kind: ResidualData::None },
        )
    }
    fn vjp_input(&self, _res: &Residual, grad_out: &Tensor) -> Tensor {
        grad_out.clone()
    }
    fn vjp_params(&self, _x: &Tensor, _grad_out: &Tensor) -> Vec<Tensor> {
        Vec::new()
    }
    fn vijp(&self, _res: &Residual, _h_in: &Tensor) -> Result<Tensor, LayerError> {
        Err(LayerError::NotSubmersive {
            layer: self.name(),
            reason: "the submersivity claim was a lie".into(),
        })
    }
    fn jvp_input(&self, _x: &Tensor, u: &Tensor) -> Tensor {
        u.clone()
    }
    fn jvp_params(&self, x: &Tensor, _dparams: &[Tensor]) -> Tensor {
        Tensor::zeros(x.shape())
    }
    fn inverse(&self, _y: &Tensor) -> Result<Tensor, LayerError> {
        Err(LayerError::NotInvertible {
            layer: self.name(),
            reason: "identity in forward only".into(),
        })
    }
    fn submersivity(&self) -> Submersivity {
        Submersivity::Submersive { fast_path: true }
    }
    fn params(&self) -> Vec<&Tensor> {
        Vec::new()
    }
    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        Vec::new()
    }
}

#[test]
fn moonwalk_vijp_failure_names_layer_index_and_layer() {
    let mut rng = Rng::new(51);
    let net = Network::new(vec![
        Box::new(Dense::new(4, 4, true, &mut rng)),
        Box::new(LyingLayer),
        Box::new(Dense::new(4, 2, true, &mut rng)),
    ]);
    let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
    let err = Moonwalk::new(MoonwalkOpts::default())
        .compute(&net, &x, &MeanLoss)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("layer 1"), "missing layer index: {msg}");
    assert!(msg.contains("liar"), "missing layer name: {msg}");
}

#[test]
fn planned_vijp_failure_names_layer_index_and_layer() {
    let mut rng = Rng::new(52);
    let net = Network::new(vec![
        Box::new(Dense::new(4, 4, true, &mut rng)),
        Box::new(LyingLayer),
        Box::new(Dense::new(4, 2, true, &mut rng)),
    ]);
    let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
    // A tight budget forces the vijp strategy onto the lying layer.
    let probes = probe_network(&net, x.shape(), DEFAULT_FRAG_BLOCKS).unwrap();
    let budget = build_frontier(&probes).min_peak();
    let err = PlannedEngine::with_budget(Some(budget))
        .compute(&net, &x, &MeanLoss)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("layer 1"), "missing layer index: {msg}");
    assert!(msg.contains("liar"), "missing layer name: {msg}");
}

#[test]
fn revbackprop_inverse_failure_names_layer_index_and_layer() {
    let net = Network::new(vec![
        Box::new(LeakyRelu::new(0.2)),
        Box::new(MaxPool2d::new(2)),
    ]);
    let mut rng = Rng::new(53);
    let x = Tensor::randn(&[1, 4, 4, 2], 1.0, &mut rng);
    let err = RevBackprop.compute(&net, &x, &MeanLoss).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("layer 1"), "missing layer index: {msg}");
    assert!(msg.contains("maxpool2d"), "missing layer name: {msg}");
}

// ---------------------------------------------------------------------------
// Parameter wire format on block topologies.
// ---------------------------------------------------------------------------

#[test]
fn export_import_roundtrips_block_topologies_bit_exactly() {
    for (trial, variant) in [
        RevNetVariant::Coupling,
        RevNetVariant::Momentum,
        RevNetVariant::Residual,
        RevNetVariant::Mixed,
    ]
    .into_iter()
    .enumerate()
    {
        let spec = RevNetSpec { channels: 8, depth: 5, variant, ..Default::default() };
        let mut src_rng = Rng::new(61 + trial as u64);
        let src = build_revnet(&spec, &mut src_rng);
        let exported = src.export_params();
        // A differently-initialised twin adopts the snapshot…
        let mut dst_rng = Rng::new(900 + trial as u64);
        let mut dst = build_revnet(&spec, &mut dst_rng);
        dst.import_params(&exported).unwrap();
        // …and re-exports it bit-for-bit.
        let reexported = dst.export_params();
        assert_eq!(exported.len(), reexported.len());
        for (a, b) in exported.iter().flatten().zip(reexported.iter().flatten()) {
            assert_eq!(a.shape(), b.shape());
            for (va, vb) in a.data().iter().zip(b.data()) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
        // Identical params ⇒ identical forward.
        let mut x_rng = Rng::new(7);
        let x = Tensor::randn(&[2, 8], 1.0, &mut x_rng);
        assert_eq!(
            src.forward(&x).data(),
            dst.forward(&x).data(),
            "imported twin must forward identically"
        );
    }
}

#[test]
fn import_params_shape_mismatch_is_a_named_error() {
    let mut rng = Rng::new(62);
    let wide = build_revnet(
        &RevNetSpec { channels: 16, depth: 3, ..Default::default() },
        &mut rng,
    );
    let mut narrow = build_revnet(
        &RevNetSpec { channels: 8, depth: 3, ..Default::default() },
        &mut rng,
    );
    let err = narrow.import_params(&wide.export_params()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("layer 0"), "error must name the layer: {msg}");

    let deeper = build_revnet(
        &RevNetSpec { channels: 8, depth: 4, ..Default::default() },
        &mut rng,
    );
    let err = narrow.import_params(&deeper.export_params()).unwrap_err();
    assert!(format!("{err:#}").contains("depth mismatch"));
}

/// Wire-encode a parameter snapshot the way the broadcast path does.
fn encode_params(params: &[Vec<Tensor>]) -> Vec<u8> {
    let borrowed: Vec<Vec<&Tensor>> =
        params.iter().map(|l| l.iter().collect()).collect();
    let mut buf = Vec::new();
    wire::write_params(&mut buf, &borrowed).unwrap();
    buf
}

#[test]
fn params_wire_roundtrip_on_block_topology() {
    let mut rng = Rng::new(63);
    let net = build_revnet(
        &RevNetSpec { channels: 8, depth: 4, variant: RevNetVariant::Mixed, ..Default::default() },
        &mut rng,
    );
    let exported = net.export_params();
    let buf = encode_params(&exported);
    match wire::read_msg(&mut Cursor::new(&buf)).unwrap() {
        wire::Msg::Params { layers } => {
            assert_eq!(layers.len(), exported.len());
            for (a, b) in exported.iter().flatten().zip(layers.iter().flatten()) {
                assert_eq!(a.shape(), b.shape());
                for (va, vb) in a.data().iter().zip(b.data()) {
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
            }
        }
        other => panic!("expected Params, got {other:?}"),
    }
}

#[test]
fn corrupt_and_truncated_param_blobs_are_named_errors_not_panics() {
    let mut rng = Rng::new(64);
    let net = build_revnet(
        &RevNetSpec { channels: 8, depth: 3, ..Default::default() },
        &mut rng,
    );
    let buf = encode_params(&net.export_params());

    // Truncated stream: reader reports the frame tag, no panic.
    let err = wire::read_msg(&mut Cursor::new(&buf[..buf.len() - 3])).unwrap_err();
    assert!(format!("{err}").contains("frame tag"), "{err}");

    // Corrupt payload (truncated mid-tensor): decode names the peer.
    let tag = buf[0];
    let payload = &buf[5..];
    let err = wire::decode_frame(tag, &payload[..payload.len() - 2], "unit-test peer")
        .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("unit-test peer"), "{msg}");
    assert!(msg.contains("corrupt frame"), "{msg}");

    // Oversized length header: rejected before any allocation.
    let mut huge = buf.clone();
    huge[1] = 0xff;
    huge[2] = 0xff;
    huge[3] = 0xff;
    huge[4] = 0xff;
    let err = wire::read_msg(&mut Cursor::new(&huge)).unwrap_err();
    assert!(format!("{err}").contains("exceeds"), "{err}");
}

// ---------------------------------------------------------------------------
// Slow full matrix (MOONWALK_SLOW_TESTS=1 via --include-ignored).
// ---------------------------------------------------------------------------

#[test]
#[ignore = "full variant × engine × thread matrix at depth 128; run with --include-ignored"]
fn full_depth_matrix_slow() {
    let _pin = pin_lock();
    for variant in [
        RevNetVariant::Coupling,
        RevNetVariant::Momentum,
        RevNetVariant::Residual,
        RevNetVariant::Mixed,
    ] {
        let mut rng = Rng::new(71);
        let net = build_revnet(
            &RevNetSpec { channels: 8, depth: 128, variant, ..Default::default() },
            &mut rng,
        );
        let x = Tensor::randn(&[4, 8], 1.0, &mut rng);
        for threads in [1usize, 4] {
            pool::with_threads(threads, || {
                assert_engines_match(&net, &x, &exact_engines(), 1e-2);
            });
        }
    }
}
