//! Randomized stress grid for the persistent worker runtime (ISSUE 2
//! tentpole): interleaved regions of wildly varying task counts,
//! resize-between-regions, oversubscription (tasks ≫ workers), and
//! panic-in-worker recovery. **Every case asserts 1-thread vs N-thread
//! bit-equality** — the payloads are chosen so their reductions are
//! exactly associative (integer-valued sums, wrapping u64 arithmetic),
//! hence any fixed partitioning must reproduce the serial bits, and
//! disjoint-write fills are bit-equal by construction.
//!
//! The thread count is process-global, so every test serializes through
//! a file-local mutex and pins counts via `pool::with_threads` (which
//! restores the previous setting even on panic).

use std::sync::Mutex;

use moonwalk::runtime::pool;
use moonwalk::util::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    match LOCK.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// FNV-style bit hash over f32 payloads (exact — compares bits).
fn hash_f32(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for x in xs {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// One randomized "program": a fixed seed drives a sequence of
/// interleaved parallel regions (fills, u64 reductions, f64
/// integer-exact reductions, span kernels) and returns a trace of
/// bit-exact digests. The trace must be identical at every thread count.
fn run_program(seed: u64, threads: usize) -> Vec<u64> {
    pool::with_threads(threads, || {
        let mut rng = Rng::new(seed);
        let mut trace: Vec<u64> = Vec::new();
        for _ in 0..12 {
            match rng.below(4) {
                0 => {
                    // Disjoint-write fill over records of random geometry.
                    let n = 1 + rng.below(257);
                    let rl = 1 + rng.below(7);
                    let salt = (rng.next_u64() % 1000) as usize;
                    let mut data = vec![0f32; n * rl];
                    pool::run_records(&mut data, rl, threads, |recs, chunk| {
                        for (local, rec) in recs.enumerate() {
                            for j in 0..rl {
                                chunk[local * rl + j] =
                                    (((rec * 31 + j * 7 + salt) % 997) as f32).sqrt();
                            }
                        }
                    });
                    trace.push(hash_f32(&data));
                }
                1 => {
                    // Oversubscribed u64 reduction: tasks ≫ workers;
                    // wrapping adds are exactly associative, so the
                    // merge order cannot change the result.
                    let n = 1 + rng.below(5000);
                    let salt = rng.next_u64();
                    let sum = pool::run_reduce(
                        n,
                        threads,
                        || 0u64,
                        |r, acc| {
                            for i in r {
                                *acc = acc.wrapping_add(
                                    (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ salt,
                                );
                            }
                        },
                        |a, b| *a = a.wrapping_add(b),
                    );
                    trace.push(sum);
                }
                2 => {
                    // f64 reduction over small integers: every partial
                    // sum stays well below 2^53, so fp addition is exact
                    // and association-free — bit-equal at any count.
                    let n = 1 + rng.below(2000);
                    let sum = pool::run_reduce(
                        n,
                        threads,
                        || 0f64,
                        |r, acc| {
                            for i in r {
                                *acc += ((i * i) % 4096) as f64;
                            }
                        },
                        |a, b| *a += b,
                    );
                    trace.push(sum.to_bits());
                }
                _ => {
                    // Irregular spans with gaps (the fragment-block shape).
                    let n_spans = 1 + rng.below(40);
                    let mut spans = Vec::with_capacity(n_spans);
                    let mut at = 0usize;
                    for _ in 0..n_spans {
                        at += rng.below(5); // gap
                        let len = 1 + rng.below(9);
                        spans.push(at..at + len);
                        at += len;
                    }
                    let mut data = vec![-1f32; at + rng.below(4)];
                    pool::run_spans(&mut data, &spans, threads, |idx, chunk| {
                        for (o, c) in chunk.iter_mut().enumerate() {
                            *c = ((idx * 131 + o * 17) % 509) as f32;
                        }
                    });
                    trace.push(hash_f32(&data));
                }
            }
        }
        trace
    })
}

#[test]
fn stress_randomized_region_grid_bit_equal() {
    let _g = lock();
    let mut rng = Rng::new(0xa11c_e5ee);
    for trial in 0..20 {
        let seed = rng.next_u64();
        let serial = run_program(seed, 1);
        for &t in &[2usize, 3, 4, 8] {
            let par = run_program(seed, t);
            assert_eq!(
                serial, par,
                "trace diverged: trial {trial} seed {seed} threads {t}"
            );
        }
    }
}

#[test]
fn resize_between_regions_matches_serial() {
    let _g = lock();
    // The same region sequence, once fully serial and once with the team
    // resized 1 → N → 1 (and grown past its previous size) between
    // regions; every region's output must be bit-identical.
    let region = |i: usize, threads: usize| -> u64 {
        let n = 64 + i * 37;
        let mut data = vec![0f32; n];
        pool::run_records(&mut data, 1, threads, |recs, chunk| {
            for (local, rec) in recs.enumerate() {
                chunk[local] = ((rec * 31 + i) as f32).sqrt();
            }
        });
        hash_f32(&data)
    };
    let sizes = [1usize, 4, 1, 3, 8, 1, 2, 6, 1, 4];
    let serial: Vec<u64> = pool::with_threads(1, || (0..sizes.len()).map(|i| region(i, 1)).collect());
    let resized: Vec<u64> = {
        let before = pool::threads();
        let out = (0..sizes.len())
            .map(|i| {
                pool::set_threads(sizes[i]);
                region(i, sizes[i])
            })
            .collect();
        pool::set_threads(before);
        out
    };
    assert_eq!(serial, resized, "resize-between-regions changed results");
}

#[test]
fn oversubscription_extreme_tasks_per_worker() {
    let _g = lock();
    // 20_000 single-element records on a 2-worker team, plus a reduce
    // with 50_000 tasks — far beyond the worker count.
    let fill = |threads: usize| {
        pool::with_threads(threads, || {
            let mut data = vec![0f32; 20_000];
            pool::run_records(&mut data, 1, threads, |recs, chunk| {
                for (local, rec) in recs.enumerate() {
                    chunk[local] = (rec % 4093) as f32;
                }
            });
            hash_f32(&data)
        })
    };
    assert_eq!(fill(1), fill(2));
    assert_eq!(fill(1), fill(4));
    let reduce = |threads: usize| {
        pool::with_threads(threads, || {
            pool::run_reduce(
                50_000,
                threads,
                || 0u64,
                |r, acc| {
                    for i in r {
                        *acc = acc.wrapping_add(i as u64);
                    }
                },
                |a, b| *a = a.wrapping_add(b),
            )
        })
    };
    let expect = (50_000u64 - 1) * 50_000 / 2;
    assert_eq!(reduce(1), expect);
    assert_eq!(reduce(4), expect);
}

#[test]
fn panic_in_worker_share_recovers() {
    let _g = lock();
    pool::with_threads(4, || {
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut data = vec![0f32; 64];
            pool::run_records(&mut data, 1, 4, |recs, chunk| {
                if recs.start >= 32 {
                    panic!("injected worker panic");
                }
                for (local, rec) in recs.enumerate() {
                    chunk[local] = rec as f32;
                }
            });
        }));
        assert!(boom.is_err(), "worker panic must propagate to the caller");
        // The team recovers: later regions run and still match serial.
        let run = |threads: usize| {
            let mut data = vec![0f32; 97];
            pool::run_records(&mut data, 1, threads, |recs, chunk| {
                for (local, rec) in recs.enumerate() {
                    chunk[local] = (rec as f32).sqrt();
                }
            });
            hash_f32(&data)
        };
        assert_eq!(run(1), run(4), "post-panic regions must stay bit-equal");
    });
}

#[test]
fn panic_in_caller_share_recovers() {
    let _g = lock();
    pool::with_threads(4, || {
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut data = vec![0f32; 64];
            pool::run_records(&mut data, 1, 4, |recs, _chunk| {
                // Share 0 (records 0..16) runs on the calling thread.
                if recs.start == 0 {
                    panic!("injected caller-share panic");
                }
            });
        }));
        assert!(boom.is_err(), "caller-share panic must propagate");
        // Workers were not poisoned by the caller's panic.
        let mut data = vec![0f32; 64];
        pool::run_records(&mut data, 1, 4, |recs, chunk| {
            for (local, rec) in recs.enumerate() {
                chunk[local] = rec as f32;
            }
        });
        let expect: Vec<f32> = (0..64).map(|r| r as f32).collect();
        assert_eq!(data, expect);
    });
}

#[test]
fn panic_in_reduce_share_recovers() {
    let _g = lock();
    pool::with_threads(4, || {
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool::run_reduce(
                100,
                4,
                || 0u64,
                |r, acc| {
                    if r.start >= 50 {
                        panic!("injected reduce panic");
                    }
                    for i in r {
                        *acc += i as u64;
                    }
                },
                |a, b| *a += b,
            )
        }));
        assert!(boom.is_err(), "reduce panic must propagate");
        let sum = pool::run_reduce(
            100,
            4,
            || 0u64,
            |r, acc| {
                for i in r {
                    *acc += i as u64;
                }
            },
            |a, b| *a += b,
        );
        assert_eq!(sum, 99 * 100 / 2, "post-panic reduce must be exact");
    });
}

#[test]
fn interleaved_nested_kernels_stay_serial_and_exact() {
    let _g = lock();
    // A region whose shares run nested region calls: the nested calls
    // must serialize (no worker re-entry) and the combined result must be
    // bit-equal to the fully serial execution.
    let run = |threads: usize| {
        pool::with_threads(threads, || {
            let mut data = vec![0f32; 24];
            pool::run_records(&mut data, 1, threads, |recs, chunk| {
                assert!(pool::effective_threads(1000) == 1 || !pool::in_worker());
                for (local, rec) in recs.enumerate() {
                    let mut inner = vec![0f32; 8];
                    pool::run_records(&mut inner, 1, 4, |ir, ic| {
                        for (l, i) in ir.enumerate() {
                            ic[l] = ((rec * 8 + i) as f32).sqrt();
                        }
                    });
                    chunk[local] = inner.iter().sum();
                }
            });
            hash_f32(&data)
        })
    };
    assert_eq!(run(1), run(3));
    assert_eq!(run(1), run(4));
}

#[test]
fn lifecycle_stats_settle_after_regions() {
    let _g = lock();
    pool::with_threads(4, || {
        let before = pool::stats();
        for _ in 0..5 {
            let mut data = vec![0f32; 40];
            pool::run_records(&mut data, 1, 4, |recs, chunk| {
                for (local, rec) in recs.enumerate() {
                    chunk[local] = rec as f32;
                }
            });
        }
        let after = pool::stats();
        assert_eq!(after.regions - before.regions, 5, "5 regions dispatched");
        assert_eq!(after.wakes - before.wakes, 15, "3 worker wakes per region");
        // Every wake parks again before the region returns.
        assert_eq!(
            after.parks - before.parks,
            15,
            "all woken workers parked again"
        );
        assert!(after.workers_spawned >= 3);
    });
}
