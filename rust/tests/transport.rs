//! Multi-process transport contract (ISSUE 4):
//!
//! 1. **Wire format** — randomized message round trips are bit-exact
//!    (f32 payloads travel as raw little-endian bits).
//! 2. **Transport equivalence** — the unix-socket transport at
//!    replicas = 2 produces gradients **bit-identical** to the
//!    in-process transport at the same replica count (worker
//!    subprocesses run the same serial kernel paths as
//!    nested-suppressed in-process replicas, and the coordinator folds
//!    both in replica order), and fp-equivalent (≤ 1e-5) to
//!    replicas = 1 across the exact-engine grid.
//! 3. **Failure semantics** — a worker killed out from under the
//!    coordinator fails that step with an error naming the replica, and
//!    the next broadcast respawns it so the group keeps serving.
//! 4. **End-to-end** — the trainer runs whole steps (param broadcast +
//!    sharded compute + streamed reduce) through worker subprocesses.
//!
//! Worker subprocesses are the real `moonwalk` binary
//! (`CARGO_BIN_EXE_moonwalk`) re-invoked in its hidden
//! `--replica-worker` mode. Tests that pin the process-global pool
//! thread count serialize through a local mutex (same pattern as the
//! other suites).

use std::sync::Mutex;

use moonwalk::autodiff::{engine_by_name, EXACT_ENGINES};
use moonwalk::distributed::transport::{
    EngineSpec, LossSpec, ShardSpec, Transport, UnixTransport, UnixTransportOpts, WireLoss,
};
use moonwalk::distributed::{split_batch, ReduceOp, ReplicaGroup};
use moonwalk::model::config::Config;
use moonwalk::model::Network;
use moonwalk::nn::SoftmaxCrossEntropy;
use moonwalk::runtime::pool;
use moonwalk::tensor::{rel_err, Tensor};
use moonwalk::util::json::Json;
use moonwalk::util::Rng;

/// Serializes the tests that pin the (process-global) pool thread count.
static THREAD_PIN: Mutex<()> = Mutex::new(());

fn pin_lock() -> std::sync::MutexGuard<'static, ()> {
    match THREAD_PIN.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// The tiny CNN the equivalence grid runs on, as a `Config` so the
/// worker subprocesses can rebuild the identical architecture.
fn tiny_cfg(seed: u64) -> Config {
    Config::from_json(
        &Json::parse(&format!(
            r#"{{"arch": "cnn2d", "depth": 2, "channels": 5, "input_hw": 16,
                 "cin": 2, "classes": 4, "alpha": 0.1, "constrained": true,
                 "seed": {seed}}}"#
        ))
        .unwrap(),
    )
    .unwrap()
}

fn tiny_net(cfg: &Config) -> Network {
    let mut rng = Rng::new(cfg.seed);
    cfg.build_network(&mut rng)
}

/// A spawned unix transport for `replicas` workers of `cfg` + `engine`,
/// pointed at the built `moonwalk` binary.
fn unix_transport(cfg: &Config, engine: EngineSpec, replicas: usize) -> UnixTransport {
    let mut opts = UnixTransportOpts::new(replicas, cfg.to_json().to_string(), engine);
    opts.worker_bin = Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_moonwalk")));
    UnixTransport::spawn(opts).expect("spawn unix transport")
}

/// Run one collected step through any transport.
fn step_collect(
    t: &mut dyn Transport,
    net: &Network,
    engine: &dyn moonwalk::autodiff::GradEngine,
    xs: &[Tensor],
    labels: &[usize],
    op: ReduceOp,
) -> anyhow::Result<(f32, Vec<Vec<Tensor>>)> {
    let per = labels.len() / xs.len();
    let shards: Vec<ShardSpec<'_>> = xs
        .iter()
        .enumerate()
        .map(|(r, x)| ShardSpec {
            x,
            loss: LossSpec::SoftmaxXent(&labels[r * per..(r + 1) * per]),
        })
        .collect();
    let grads: Mutex<Vec<Vec<Tensor>>> =
        Mutex::new((0..net.depth()).map(|_| Vec::new()).collect());
    let step = t.step(net, engine, &shards, op, &|li, g| {
        grads.lock().unwrap()[li] = g;
    })?;
    Ok((step.loss, grads.into_inner().unwrap()))
}

// ---------------------------------------------------------------------------
// 1. Wire-format round trips
// ---------------------------------------------------------------------------

/// Randomized round-trip property: every message family survives
/// encode→decode bit-exactly — shapes, labels, and raw f32 payload bits
/// (including negative zero and subnormals).
#[test]
fn wire_roundtrip_randomized_property() {
    use moonwalk::distributed::transport::wire;
    let mut rng = Rng::new(42);
    for trial in 0..40 {
        let rank = rng.below(4) + 1;
        let shape: Vec<usize> = (0..rank).map(|_| rng.below(5) + 1).collect();
        let n: usize = shape.iter().product();
        // Payload mixes exact small integers with awkward fp values.
        let data: Vec<f32> = (0..n)
            .map(|i| match i % 4 {
                0 => (rng.below(64) as f32) - 32.0,
                1 => -0.0,
                2 => f32::MIN_POSITIVE * (i as f32 + 1.0),
                _ => (rng.uniform() as f32) * 1e3,
            })
            .collect();
        let t = Tensor::from_vec(data, &shape);

        // Step frame (tensor + labels).
        let labels: Vec<usize> = (0..rng.below(6) + 1).map(|_| rng.below(10)).collect();
        let loss = if trial % 2 == 0 {
            WireLoss::Mean
        } else {
            WireLoss::SoftmaxXent(labels.clone())
        };
        let mut buf = Vec::new();
        wire::write_step(&mut buf, &t, &loss).unwrap();
        match wire::read_msg(&mut buf.as_slice()).unwrap() {
            wire::Msg::Step { x, loss: got } => {
                assert_eq!(x.shape(), t.shape(), "trial {trial}: shape");
                for (a, b) in x.data().iter().zip(t.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "trial {trial}: payload bits");
                }
                assert_eq!(got, loss, "trial {trial}: loss spec");
            }
            other => panic!("trial {trial}: wrong message {other:?}"),
        }

        // Grad frame (multi-tensor).
        let g2 = Tensor::from_vec(vec![0.5; 3], &[3]);
        let grads = vec![t.clone(), g2];
        let mut buf = Vec::new();
        wire::write_grad(&mut buf, trial as u32, &grads).unwrap();
        match wire::read_msg(&mut buf.as_slice()).unwrap() {
            wire::Msg::Grad { layer, grads: got } => {
                assert_eq!(layer, trial as u32);
                assert_eq!(got.len(), 2);
                for (a, b) in got[0].data().iter().zip(t.data()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("trial {trial}: wrong message {other:?}"),
        }

        // Params frame (two layers, second parameter-free).
        let layers: Vec<Vec<&Tensor>> = vec![vec![&t], vec![]];
        let mut buf = Vec::new();
        wire::write_params(&mut buf, &layers).unwrap();
        match wire::read_msg(&mut buf.as_slice()).unwrap() {
            wire::Msg::Params { layers: got } => {
                assert_eq!(got.len(), 2);
                assert_eq!(got[0][0].shape(), t.shape());
                assert!(got[1].is_empty());
            }
            other => panic!("trial {trial}: wrong message {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Transport equivalence
// ---------------------------------------------------------------------------

/// Unix-socket replicas = 2 must be **bit-identical** to in-process
/// replicas = 2: per-replica computation runs the same serial kernel
/// paths (worker threads pinned to 1 ⇔ nested suppression in-process),
/// payloads travel bit-exactly, and both transports fold the same
/// replica-ordered reduce.
#[test]
fn unix_bit_identical_to_local_at_equal_replicas() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(0);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(1);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![0usize, 3, 1, 2];
    let xs = split_batch(&x, 2).unwrap();
    for name in ["backprop", "moonwalk"] {
        let engine = engine_by_name(name, cfg.block, cfg.checkpoint_every, cfg.seed).unwrap();
        // In-process reference at replicas = 2 (nested suppression).
        let group = ReplicaGroup::new(2).unwrap();
        let (local_loss, local_grads) = pool::with_threads(4, || {
            let shards: Vec<ShardSpec<'_>> = xs
                .iter()
                .enumerate()
                .map(|(r, x)| ShardSpec {
                    x,
                    loss: LossSpec::SoftmaxXent(&labels[r * 2..(r + 1) * 2]),
                })
                .collect();
            let out = group
                .step(&net, engine.as_ref(), &shards, ReduceOp::Mean)
                .unwrap();
            (out.loss, out.grads)
        });
        // The same step through worker subprocesses.
        let mut unix = unix_transport(&cfg, EngineSpec::new(name), 2);
        unix.broadcast(&net).unwrap();
        let (unix_loss, unix_grads) =
            step_collect(&mut unix, &net, engine.as_ref(), &xs, &labels, ReduceOp::Mean)
                .unwrap();
        assert_eq!(
            unix_loss.to_bits(),
            local_loss.to_bits(),
            "{name}: loss must be bit-identical across transports"
        );
        for (li, (a, b)) in local_grads.iter().zip(&unix_grads).enumerate() {
            assert_eq!(a.len(), b.len(), "{name} layer {li}: gradient arity");
            for (pi, (ga, gb)) in a.iter().zip(b).enumerate() {
                assert_eq!(ga.shape(), gb.shape(), "{name} layer {li} param {pi}");
                for (va, vb) in ga.data().iter().zip(gb.data()) {
                    assert_eq!(
                        va.to_bits(),
                        vb.to_bits(),
                        "{name} layer {li} param {pi}: unix vs local bits"
                    );
                }
            }
        }
    }
}

/// Unix replicas = 2 is fp-equivalent (≤ 1e-5) to in-process
/// replicas = 1 at the same effective batch for every exact engine —
/// the transport extension of the PR 3 equivalence grid.
#[test]
fn unix_replicas_match_single_replica_for_exact_engines() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(2);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(3);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![1usize, 2, 0, 3];
    let xs = split_batch(&x, 2).unwrap();
    for name in EXACT_ENGINES {
        let engine = engine_by_name(name, 4, 2, 0).unwrap();
        let full_loss = SoftmaxCrossEntropy::new(labels.clone());
        let reference = pool::with_threads(4, || {
            ReplicaGroup::new(1)
                .unwrap()
                .compute(
                    &net,
                    engine.as_ref(),
                    &[moonwalk::distributed::Shard {
                        x: &x,
                        loss: &full_loss,
                    }],
                    ReduceOp::Mean,
                )
                .unwrap()
        });
        let spec = EngineSpec {
            name: name.to_string(),
            block: 4,
            checkpoint_segments: 2,
            seed: 0,
        };
        let mut unix = unix_transport(&cfg, spec, 2);
        unix.broadcast(&net).unwrap();
        let (loss, grads) =
            step_collect(&mut unix, &net, engine.as_ref(), &xs, &labels, ReduceOp::Mean)
                .unwrap();
        assert!(
            (loss - reference.loss).abs() <= 1e-5 * reference.loss.abs().max(1.0),
            "{name}: loss {loss} vs {}",
            reference.loss
        );
        for (li, (a, b)) in reference.grads.iter().zip(&grads).enumerate() {
            assert_eq!(a.len(), b.len(), "{name} layer {li}: arity");
            for (pi, (ga, gb)) in a.iter().zip(b).enumerate() {
                let err = rel_err(gb, ga);
                assert!(
                    err <= 1e-5,
                    "{name} layer {li} param {pi}: rel err {err} > 1e-5"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Failure semantics
// ---------------------------------------------------------------------------

/// A worker killed out from under the coordinator fails the step with an
/// error naming the replica; the next broadcast respawns it and the
/// group serves the following step with correct (bit-identical) results.
#[test]
fn worker_death_fails_step_then_group_recovers() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(4);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(5);
    let x = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![0usize, 1, 2, 3];
    let xs = split_batch(&x, 2).unwrap();
    let engine = engine_by_name("backprop", 4, 0, 0).unwrap();
    let mut unix = unix_transport(&cfg, EngineSpec::new("backprop"), 2);
    unix.broadcast(&net).unwrap();
    let (loss0, grads0) =
        step_collect(&mut unix, &net, engine.as_ref(), &xs, &labels, ReduceOp::Mean).unwrap();

    // Kill replica 1's subprocess without telling the transport, so the
    // failure is discovered mid-step exactly as a real crash would be.
    assert!(unix.worker_ids()[1].is_some(), "replica 1 alive");
    unix.simulate_worker_crash(1).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(50));

    let err = step_collect(&mut unix, &net, engine.as_ref(), &xs, &labels, ReduceOp::Mean)
        .expect_err("step against a dead worker must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("replica 1"), "error should name the replica: {msg}");

    // Recovery: broadcast respawns the dead worker and re-uploads the
    // parameters; the next step matches the pre-crash one bit-for-bit.
    unix.broadcast(&net).unwrap();
    assert!(unix.worker_ids().iter().all(|p| p.is_some()), "respawned");
    let (loss1, grads1) =
        step_collect(&mut unix, &net, engine.as_ref(), &xs, &labels, ReduceOp::Mean).unwrap();
    assert_eq!(loss1.to_bits(), loss0.to_bits(), "post-recovery loss");
    for (a, b) in grads0.iter().zip(&grads1) {
        for (ga, gb) in a.iter().zip(b) {
            assert_eq!(ga.data(), gb.data(), "post-recovery grads bit-identical");
        }
    }
}

/// The coordinator's own fault-injection kill marks the replica
/// unsynced: stepping without a broadcast is rejected up front, and a
/// broadcast restores service.
#[test]
fn kill_worker_requires_rebroadcast_before_stepping() {
    let _pin = pin_lock();
    let cfg = tiny_cfg(6);
    let net = tiny_net(&cfg);
    let mut rng = Rng::new(7);
    let x = Tensor::randn(&[2, 16, 16, 2], 1.0, &mut rng);
    let labels = vec![0usize, 3];
    let xs = split_batch(&x, 2).unwrap();
    let engine = engine_by_name("backprop", 4, 0, 0).unwrap();
    let mut unix = unix_transport(&cfg, EngineSpec::new("backprop"), 2);
    unix.broadcast(&net).unwrap();
    unix.kill_worker(0).unwrap();
    let err = step_collect(&mut unix, &net, engine.as_ref(), &xs, &labels, ReduceOp::Mean)
        .expect_err("unsynced group must refuse to step");
    assert!(format!("{err:#}").contains("broadcast"), "{err:#}");
    unix.broadcast(&net).unwrap();
    step_collect(&mut unix, &net, engine.as_ref(), &xs, &labels, ReduceOp::Mean)
        .expect("group must serve again after rebroadcast");
}

// ---------------------------------------------------------------------------
// 4. End-to-end training through subprocesses
// ---------------------------------------------------------------------------

/// The full trainer loop — per-step parameter broadcast, sharded
/// compute in worker subprocesses, streamed reduce, optimizer apply —
/// runs end-to-end over the unix transport and records it in the
/// metrics.
#[test]
fn trainer_end_to_end_over_unix_transport() {
    use moonwalk::coordinator::{Optimizer, OptimizerKind, SyntheticSpec, TextureDataset, Trainer};
    let _pin = pin_lock();
    let cfg = tiny_cfg(8);
    let mut net = tiny_net(&cfg);
    let data = TextureDataset::generate(
        SyntheticSpec {
            hw: 16,
            cin: 2,
            classes: 4,
            noise: 0.15,
            seed: 8,
        },
        40,
    );
    let (train, test) = data.split(0.2);
    let engine = engine_by_name("moonwalk", cfg.block, cfg.checkpoint_every, cfg.seed).unwrap();
    let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
    let unix = unix_transport(&cfg, EngineSpec::new("moonwalk"), 2);
    let mut trainer = Trainer::new(&mut net, engine.as_ref(), opt);
    trainer.replicas = 2;
    trainer.log_every = 1;
    trainer.transport = Some(Box::new(unix));
    let dir = std::env::temp_dir().join("moonwalk_transport_e2e_test");
    let path = dir.join("metrics.jsonl");
    let mut rng = Rng::new(9);
    let report = trainer
        .train(&train, &test, 4, 3, &mut rng, Some(&path))
        .unwrap();
    assert_eq!(report.replicas, 2);
    assert_eq!(report.transport, "unix");
    assert!(report.final_loss.is_finite());
    let text = std::fs::read_to_string(&path).unwrap();
    let first = Json::parse(text.lines().next().unwrap()).unwrap();
    assert_eq!(first.req_str("transport").unwrap(), "unix");
    assert_eq!(first.req_usize("replicas").unwrap(), 2);
    std::fs::remove_dir_all(&dir).ok();
}

/// The trainer over the unix transport draws the same data sequence and
/// produces a finite, comparable loss curve to the in-process transport
/// (fp-equivalent updates ⇒ closely tracking losses).
#[test]
fn trainer_unix_matches_local_loss_curve() {
    use moonwalk::coordinator::{Optimizer, OptimizerKind, SyntheticSpec, TextureDataset, Trainer};
    let _pin = pin_lock();
    let cfg = tiny_cfg(10);
    let data = TextureDataset::generate(
        SyntheticSpec {
            hw: 16,
            cin: 2,
            classes: 4,
            noise: 0.15,
            seed: 10,
        },
        40,
    );
    let (train, test) = data.split(0.2);
    let run = |transport: Option<Box<dyn Transport>>| {
        let mut net = tiny_net(&cfg);
        let engine =
            engine_by_name("backprop", cfg.block, cfg.checkpoint_every, cfg.seed).unwrap();
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
        let mut trainer = Trainer::new(&mut net, engine.as_ref(), opt);
        trainer.replicas = 2;
        trainer.transport = transport;
        let mut rng = Rng::new(11);
        trainer.train(&train, &test, 4, 4, &mut rng, None).unwrap()
    };
    let local = run(None);
    let unix = run(Some(Box::new(unix_transport(
        &cfg,
        EngineSpec::new("backprop"),
        2,
    ))));
    assert_eq!(local.loss_curve.len(), unix.loss_curve.len());
    for (step, (a, b)) in local.loss_curve.iter().zip(&unix.loss_curve).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * a.abs().max(1.0),
            "step {step}: local {a} vs unix {b}"
        );
    }
}
