"""AOT compile path: lower every per-layer op of the flagship model to
HLO **text** and write ``artifacts/manifest.json`` for the Rust runtime.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts``; Python never runs after that.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import pallas_kernels as K
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_op(fn, arg_specs):
    """jit-lower an op for fixed f32 shapes; returns (hlo_text, out_shapes)."""
    lowered = jax.jit(fn).lower(*arg_specs)
    out = jax.eval_shape(fn, *arg_specs)
    if not isinstance(out, (tuple, list)):
        out = (out,)
    out_shapes = [list(o.shape) for o in out]
    return to_hlo_text(lowered), out_shapes


def build_ops(cfg: M.ModelConfig):
    """The op set the Rust Moonwalk e2e driver executes (DESIGN.md §6)."""
    from .kernels import ref

    ops = {}
    n, ch, k, s, p, alpha = (
        cfg.batch,
        cfg.channels,
        cfg.k,
        cfg.stride,
        cfg.pad,
        cfg.alpha,
    )

    # Per conv block i: shapes before/after.
    for i in range(cfg.depth):
        hin = cfg.spatial_after(i)
        hout = cfg.spatial_after(i + 1)
        x_s = spec(n, hin, hin, ch)
        y_s = spec(n, hout, hout, ch)
        w_s = spec(k, k, ch, ch)

        ops[f"conv{i}_fwd"] = (
            lambda x, w: (K.conv2d_fwd(x, w, s, p),),
            [x_s, w_s],
        )
        ops[f"conv{i}_vjp_in"] = (
            functools.partial(
                lambda g, w, xs=tuple(x_s.shape): (
                    ref.conv2d_vjp_input(g, w, xs, s, p),
                )
            ),
            [y_s, w_s],
        )
        ops[f"conv{i}_vjp_w"] = (
            lambda x, g: (ref.conv2d_vjp_w(x, g, (k, k, ch, ch), s, p),),
            [x_s, y_s],
        )
        # The paper's operator — the Pallas Alg.-2 kernel.
        ops[f"conv{i}_vijp"] = (
            lambda h, w: (K.conv2d_vijp(h, w, s, p),),
            [x_s, w_s],
        )
        ops[f"lrelu{i}_fwd"] = (
            lambda x: (K.leaky_relu_fwd(x, alpha),),
            [y_s],
        )
        ops[f"lrelu{i}_vjp"] = (
            lambda x, g: (K.leaky_relu_vjp(x, g, alpha),),
            [y_s, y_s],
        )
        ops[f"lrelu{i}_vijp"] = (
            lambda x, h: (K.leaky_relu_vijp(x, h, alpha),),
            [y_s, y_s],
        )

    # Dense head.
    din, classes = cfg.dense_in(), cfg.classes
    x2_s, w2_s, b2_s = spec(n, din), spec(din, classes), spec(classes)
    g2_s = spec(n, classes)
    ops["dense_fwd"] = (lambda x, w, b: (M.dense_fwd(x, w, b),), [x2_s, w2_s, b2_s])
    ops["dense_vjp_in"] = (lambda g, w: (M.dense_vjp_in(g, w),), [g2_s, w2_s])
    ops["dense_vjp_w"] = (
        lambda x, g: (M.dense_vjp_w(x, g), g.sum(axis=0)),
        [x2_s, g2_s],
    )
    ops["dense_vijp"] = (lambda h, w: (M.dense_vijp(h, w),), [x2_s, w2_s])

    # Loss head (scalar loss reshaped to [1] so every output is an array).
    ops["loss_grad"] = (
        lambda logits, onehot: (
            M.loss_and_grad(logits, onehot)[0].reshape(1),
            M.loss_and_grad(logits, onehot)[1],
        ),
        [g2_s, g2_s],
    )
    return ops


def emit(out_dir: str, cfg: M.ModelConfig) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "config": {
            "batch": cfg.batch,
            "hw": cfg.hw,
            "cin": cfg.cin,
            "channels": cfg.channels,
            "depth": cfg.depth,
            "classes": cfg.classes,
            "alpha": cfg.alpha,
            "k": cfg.k,
            "stride": cfg.stride,
            "pad": cfg.pad,
            "pool": cfg.pool_window(),
            "dense_in": cfg.dense_in(),
            "seed": cfg.seed,
        },
        "ops": [],
    }
    for name, (fn, arg_specs) in sorted(build_ops(cfg).items()):
        hlo, out_shapes = lower_op(fn, arg_specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        manifest["ops"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(s.shape) for s in arg_specs],
                "outputs": out_shapes,
            }
        )
        print(f"  lowered {name}: {len(hlo)} chars, outs {out_shapes}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['ops'])} ops to {out_dir}/manifest.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--hw", type=int, default=16)
    ap.add_argument("--channels", type=int, default=16)
    ap.add_argument("--depth", type=int, default=2)
    args = ap.parse_args()
    cfg = M.ModelConfig(
        batch=args.batch, hw=args.hw, channels=args.channels, depth=args.depth
    )
    emit(args.out_dir, cfg)


if __name__ == "__main__":
    main()
