"""L1: Pallas kernels for the paper's compute hot-spots.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the correctness
path and the TPU performance story is analytic (DESIGN.md §8).

Hardware adaptation (DESIGN.md §2): the paper's experiments ran CUDA
kernels under jit. On TPU the vijp's per-position channel-triangular
solve maps to a VPU-vectorized sweep over an [8,128]-tiled block of
spatial positions resident in VMEM; the kernels below express that
structure (whole-block refs + unrolled channel recurrences) rather than
a mechanical CUDA port.

Kernels:
* ``conv2d_fwd``      — strided/padded channel-last convolution.
* ``conv2d_vijp``     — the paper's novel operator, Alg. 2 fast path
                        (fully parallel over spatial positions).
* ``conv1d_fragment_reconstruct`` — Alg. 3, block-parallel fragmental
                        cotangent reconstruction.
* ``leaky_relu_fwd`` / ``leaky_relu_vjp`` / ``leaky_relu_vijp``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# ------------------------------------------------------------------ conv2d


def _conv2d_kernel(x_ref, w_ref, o_ref, *, stride, pad, k):
    """Per-tap accumulation: o += x[tap slice] @ w[tap] (sums over Cin).

    The tap loop is unrolled at trace time; each tap contributes a
    [H'W', Cin] x [Cin, Cout] matmul — the same schedule the Rust hot
    path uses, and on TPU each tap matmul maps onto the MXU.
    """
    x = x_ref[...]  # [N, H, W, Cin]
    w = w_ref[...]  # [k, k, Cin, Cout]
    n, h, ww, cin = x.shape
    cout = w.shape[3]
    ho = (h + 2 * pad - k) // stride + 1
    wo = (ww + 2 * pad - k) // stride + 1
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    acc = jnp.zeros((n, ho, wo, cout), dtype=x.dtype)
    for ki in range(k):
        for kj in range(k):
            tap = jax.lax.slice(
                xp,
                (0, ki, kj, 0),
                (n, ki + stride * (ho - 1) + 1, kj + stride * (wo - 1) + 1, cin),
                (1, stride, stride, 1),
            )  # [N, ho, wo, Cin]
            acc = acc + jnp.einsum("nabc,cd->nabd", tap, w[ki, kj])
    o_ref[...] = acc


def conv2d_fwd(x, w, stride, pad):
    """Pallas strided convolution (interpret mode)."""
    n, h, ww, cin = x.shape
    k = w.shape[0]
    cout = w.shape[3]
    ho = (h + 2 * pad - k) // stride + 1
    wo = (ww + 2 * pad - k) // stride + 1
    del cin
    return pl.pallas_call(
        functools.partial(_conv2d_kernel, stride=stride, pad=pad, k=k),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), x.dtype),
        interpret=True,
    )(x, w)


# ------------------------------------------------------------- conv2d vijp


def _conv2d_vijp_kernel(h_ref, w_ref, o_ref, *, stride, pad, k):
    """Alg. 2 (fully parallel vijp): per spatial position, a channel-
    triangular solve with pivots w[p,p,co,co]; no spatial coupling when
    s + p >= k, so every position solves independently (vectorized here,
    grid-parallel on real hardware)."""
    h = h_ref[...]  # [N, H, W, Cin] — input cotangent
    w = w_ref[...]
    n, hh, ww2, cin = h.shape
    cout = w.shape[3]
    ho = (hh + 2 * pad - k) // stride + 1
    wo = (ww2 + 2 * pad - k) // stride + 1
    del cin
    # Pivot equations live at input positions (s*a, s*b).
    hs = jax.lax.slice(
        h,
        (0, 0, 0, 0),
        (n, stride * (ho - 1) + 1, stride * (wo - 1) + 1, cout),
        (1, stride, stride, 1),
    )  # [N, ho, wo, Cout] (channel index co reads input channel co)
    wp = w[pad, pad]  # [Cin, Cout]
    cols = []
    for co in range(cout):
        acc = hs[..., co]
        for c2 in range(co):
            acc = acc - wp[co, c2] * cols[c2]
        cols.append(acc / wp[co, co])
    o_ref[...] = jnp.stack(cols, axis=-1)


def conv2d_vijp(h, w, stride, pad):
    """Pallas fully-parallel vijp (fast path s + p >= k)."""
    n, hh, ww2, _ = h.shape
    k = w.shape[0]
    cout = w.shape[3]
    assert stride + pad >= k, "Pallas vijp implements the Alg.-2 fast path"
    ho = (hh + 2 * pad - k) // stride + 1
    wo = (ww2 + 2 * pad - k) // stride + 1
    return pl.pallas_call(
        functools.partial(_conv2d_vijp_kernel, stride=stride, pad=pad, k=k),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), h.dtype),
        interpret=True,
    )(h, w)


# ------------------------------------------- conv1d fragmental (Alg. 3)


def _frag1d_kernel(h_ref, frag_ref, w_ref, o_ref, *, block, k):
    """Alg. 3: one grid program per block; restore the stored k-1 prefix
    slices, then roll the recurrence forward inside the block."""
    h = h_ref[...]       # [N, block, Cin]   input cotangent rows i-1
    frag = frag_ref[...]  # [N, k-1, Cout]   stored prefix
    w = w_ref[...]        # [k, Cin, Cout]
    n = h.shape[0]
    cout = w.shape[2]
    keep = k - 1
    del n
    rows = [frag[:, r, :] for r in range(keep)]  # each [N, Cout]
    for i in range(keep, block):
        cols = []
        for co in range(cout):
            acc = h[:, i - 1, co]
            for c2 in range(co):
                acc = acc - w[0, co, c2] * cols[c2]
            for j in range(1, k):
                prev = rows[i - j]
                acc = acc - prev @ w[j, co, :]
            cols.append(acc / w[0, co, co])
        rows.append(jnp.stack(cols, axis=-1))
    o_ref[...] = jnp.stack(rows, axis=1)


def conv1d_fragment_reconstruct(h, frag, w, block):
    """Block-parallel fragmental reconstruction (s=1, p=1 convs).

    ``h``    — [N, L] input cotangent (L a multiple of ``block``);
    ``frag`` — [N, n_blocks*(k-1), Cout] stored slices;
    returns the full output cotangent [N, L, Cout].

    The grid dimension ranges over blocks — the parallelism Alg. 3
    exploits: every block reconstructs independently from its own prefix.
    """
    n, ll, cin = h.shape
    k, cin2, cout = w.shape
    assert cin == cin2
    assert ll % block == 0, "pad the cotangent to a whole number of blocks"
    n_blocks = ll // block
    keep = k - 1
    grid = (n_blocks,)
    return pl.pallas_call(
        functools.partial(_frag1d_kernel, block=block, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block, cin), lambda b: (0, b, 0)),
            pl.BlockSpec((n, keep, cout), lambda b: (0, b, 0)),
            pl.BlockSpec((k, cin, cout), lambda b: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n, block, cout), lambda b: (0, b, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ll, cout), h.dtype),
        interpret=True,
    )(h, frag, w)


# ------------------------------------------------------------- leaky relu


def _lrelu_fwd_kernel(x_ref, o_ref, *, alpha):
    x = x_ref[...]
    o_ref[...] = jnp.where(x >= 0, x, alpha * x)


def leaky_relu_fwd(x, alpha):
    return pl.pallas_call(
        functools.partial(_lrelu_fwd_kernel, alpha=alpha),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def _lrelu_vjp_kernel(x_ref, g_ref, o_ref, *, alpha):
    x = x_ref[...]
    g = g_ref[...]
    o_ref[...] = jnp.where(x >= 0, g, alpha * g)


def leaky_relu_vjp(x, g, alpha):
    return pl.pallas_call(
        functools.partial(_lrelu_vjp_kernel, alpha=alpha),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, g)


def _lrelu_vijp_kernel(x_ref, h_ref, o_ref, *, alpha):
    """vijp of a diagonal Jacobian: divide where the slope was alpha."""
    x = x_ref[...]
    h = h_ref[...]
    o_ref[...] = jnp.where(x >= 0, h, h / alpha)


def leaky_relu_vijp(x, h, alpha):
    return pl.pallas_call(
        functools.partial(_lrelu_vijp_kernel, alpha=alpha),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, h)
