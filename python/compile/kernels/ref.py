"""Pure-jnp oracles for the Pallas kernels (the L1 correctness signal).

Conventions match the Rust layer library and the paper's §3.1/§5 notation:
channel-last tensors, kernel ``w[k, k, Cin, Cout]`` (2-D) / ``w[k, Cin,
Cout]`` (1-D), and the convolution

    x'[i', c'] = sum_{j, c} w[j, c, c'] * x[s*i' + j - p, c].
"""

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------- 2-D conv


def conv2d(x, w, stride, pad):
    """Forward convolution, batched: x [N,H,W,Cin], w [k,k,Cin,Cout]."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_vjp_input(g, w, x_shape, stride, pad):
    """h = g * dconv/dx — the transpose convolution (paper Eq. 12/13)."""
    zeros = jnp.zeros(x_shape, dtype=g.dtype)
    _, pullback = jax.vjp(lambda x: conv2d(x, w, stride, pad), zeros)
    return pullback(g)[0]


def conv2d_vjp_w(x, g, w_shape, stride, pad):
    """dw = g * dconv/dw."""
    zeros = jnp.zeros(w_shape, dtype=g.dtype)
    _, pullback = jax.vjp(lambda w: conv2d(x, w, stride, pad), zeros)
    return pullback(g)[0]


def conv2d_vijp_fast(h, w, stride, pad, out_spatial):
    """Reference fully-parallel vijp (paper Alg. 2, fast path s+p >= k).

    Recovers the output cotangent h' from the input cotangent h by the
    per-position channel-triangular solve:

        h'[a,b,co] = (h[s*a, s*b, co]
                      - sum_{c2<co} w[p,p,co,c2] * h'[a,b,c2]) / w[p,p,co,co]
    """
    k = w.shape[0]
    cout = w.shape[3]
    assert stride + pad >= k, "fast path requires s + p >= k"
    ho, wo = out_spatial
    # Strided gather of the pivot rows: h[s*a, s*b, co] for co < cout.
    hs = h[:, : stride * (ho - 1) + 1 : stride, : stride * (wo - 1) + 1 : stride, :cout]
    wp = w[pad, pad]  # [Cin, Cout]
    cols = []
    for co in range(cout):
        acc = hs[..., co]
        for c2 in range(co):
            acc = acc - wp[co, c2] * cols[c2]
        cols.append(acc / wp[co, co])
    return jnp.stack(cols, axis=-1)


def conv2d_vijp_lstsq(h, w, x_shape, stride, pad, out_shape):
    """Brute-force oracle: least-squares against the materialized Jacobian
    (single image, tiny shapes only). Solves h' J = h with J = d(conv)/dx.
    """
    assert h.shape[0] == 1, "lstsq oracle is single-image"
    n_in = int(np.prod(x_shape))
    n_out = int(np.prod(out_shape))

    def f_flat(x_flat):
        return conv2d(x_flat.reshape(x_shape), w, stride, pad).reshape(n_out)

    jac = jax.jacfwd(f_flat)(jnp.zeros(n_in))  # [n_out, n_in]
    sol, *_ = jnp.linalg.lstsq(jac.T, h.reshape(n_in))
    return sol.reshape(out_shape)


# ---------------------------------------------------------------- 1-D conv


def conv1d(x, w, stride, pad):
    """x [N,L,Cin], w [k,Cin,Cout]."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride,),
        padding=[(pad, pad)],
        dimension_numbers=("NWC", "WIO", "NWC"),
    )


def conv1d_vjp_input(g, w, x_shape, stride, pad):
    zeros = jnp.zeros(x_shape, dtype=g.dtype)
    _, pullback = jax.vjp(lambda x: conv1d(x, w, stride, pad), zeros)
    return pullback(g)[0]


def conv1d_fragment_capture(hp, block, k):
    """First k-1 slices of each block of the output cotangent (Alg. 3's
    stored ``h_init``). hp [N, L', C'] -> [N, n_blocks*(k-1), C']."""
    n, lo, cout = hp.shape
    keep = k - 1
    n_blocks = -(-lo // block)
    pad = n_blocks * block - lo
    hp_pad = jnp.pad(hp, ((0, 0), (0, pad), (0, 0)))
    blocks = hp_pad.reshape(n, n_blocks, block, cout)
    return blocks[:, :, :keep, :].reshape(n, n_blocks * keep, cout)


def conv1d_fragment_reconstruct(frag, h, w, block):
    """Reference Alg. 3 (sequential numpy): reconstruct the full output
    cotangent from fragments + input cotangent for s=1, p=1 convs."""
    k, cin, cout = w.shape
    del cin
    wnp = np.asarray(w, dtype=np.float64)
    hnp = np.asarray(h, dtype=np.float64)
    n, ll, _ = hnp.shape
    keep = k - 1
    fragnp = np.asarray(frag, dtype=np.float64)
    n_blocks = fragnp.shape[1] // keep
    lo = ll + 3 - k  # s=1, p=1 output length
    hp = np.zeros((n, lo, cout), dtype=np.float64)
    for img in range(n):
        for b in range(n_blocks):
            for r in range(keep):
                i = b * block + r
                if i < lo:
                    hp[img, i] = fragnp[img, b * keep + r]
        for b in range(n_blocks):
            for i in range(b * block + keep, min((b + 1) * block, lo)):
                for co in range(cout):
                    acc = hnp[img, i - 1, co]
                    for c2 in range(co):
                        acc -= wnp[0, co, c2] * hp[img, i, c2]
                    for j in range(1, k):
                        if j > i:
                            break
                        for c2 in range(cout):
                            acc -= wnp[j, co, c2] * hp[img, i - j, c2]
                    hp[img, i, co] = acc / wnp[0, co, co]
    return jnp.asarray(hp.astype(np.float32))


# ------------------------------------------------------------- activations


def leaky_relu(x, alpha):
    return jnp.where(x >= 0, x, alpha * x)


def leaky_relu_vjp(x, g, alpha):
    return jnp.where(x >= 0, g, alpha * g)


def leaky_relu_vijp(x, h, alpha):
    return jnp.where(x >= 0, h, h / alpha)


# ------------------------------------------------------ parameter projection


def project_submersive_2d(w, pad, floor=0.05):
    """Lemma-1 projection: zero sub-triangular entries of the pivot tap and
    floor the diagonal (mirrors Conv2d::project_submersive in Rust)."""
    k, _, cin, cout = w.shape
    del k
    wp = w[pad, pad]
    for co in range(cout):
        for ci in range(co):
            wp = wp.at[ci, co].set(0.0)
    for co in range(min(cin, cout)):
        d = wp[co, co]
        clamped = jnp.where(
            jnp.abs(d) < floor, jnp.where(d >= 0, floor, -floor), d
        )
        wp = wp.at[co, co].set(clamped)
    return w.at[pad, pad].set(wp)


def project_fragmental_1d(w, floor=0.05):
    """Appendix-10 projection: tap-0 triangularity + diagonal floor."""
    k, cin, cout = w.shape
    del k
    w0 = w[0]
    for co in range(cout):
        for ci in range(co):
            w0 = w0.at[ci, co].set(0.0)
    for co in range(min(cin, cout)):
        d = w0[co, co]
        clamped = jnp.where(
            jnp.abs(d) < floor, jnp.where(d >= 0, floor, -floor), d
        )
        w0 = w0.at[co, co].set(clamped)
    return w.at[0].set(w0)
