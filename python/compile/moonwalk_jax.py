"""Moonwalk (mixed-mode, Alg. 1) implemented directly in JAX — the L2
cross-check that the Moonwalk identity (Eq. 7) reproduces ``jax.grad``.

Works on a fully submersive conv stack (no channel expansion):
Phase I/II obtain the input cotangent h0 with ``jax.vjp`` restricted to
the input; Phase III sweeps forward recovering each layer's output
cotangent with the Pallas vijp kernel (Eq. 9) and emitting parameter
gradients with vjp (Eq. 10).
"""

import jax
import jax.numpy as jnp

from .kernels import pallas_kernels as K
from .kernels import ref


def stack_forward(ws, x, stride, pad, alpha):
    """[Conv -> LeakyReLU] x depth with mean loss (paper §6.2 sweep net)."""
    h = x
    for w in ws:
        h = ref.conv2d(h, w, stride, pad)
        h = ref.leaky_relu(h, alpha)
    return h.mean()


def grads_backprop(ws, x, stride, pad, alpha):
    """Reference gradients via jax.grad (reverse mode)."""
    return jax.grad(lambda ws_: stack_forward(ws_, x, stride, pad, alpha))(ws)


def grads_moonwalk(ws, x, stride, pad, alpha):
    """Mixed-mode Moonwalk: h0 in reverse mode, parameter grads in the
    vijp forward sweep."""
    # Phases I+II: input cotangent only.
    _, h0 = jax.value_and_grad(lambda x_: stack_forward(ws, x_, stride, pad, alpha))(x)

    # Phase III: forward sweep (Alg. 1).
    grads = []
    h = h0
    act = x
    for w in ws:
        conv_out = ref.conv2d(act, w, stride, pad)
        # Output cotangent of the conv via the Pallas vijp (Eq. 9).
        h_conv = K.conv2d_vijp(h, w, stride, pad)
        # Parameter gradient (Eq. 10).
        grads.append(ref.conv2d_vjp_w(act, h_conv, w.shape, stride, pad))
        # Push the cotangent through LeakyReLU (diagonal vijp).
        h = K.leaky_relu_vijp(conv_out, h_conv, alpha)
        act = ref.leaky_relu(conv_out, alpha)
    return grads
