"""L2: the flagship submersive CNN in JAX, calling the L1 Pallas kernels.

This module defines the model the AOT path ships to Rust: an
``Upsample -> [Conv(s=2,p=1,k=3) -> LeakyReLU] x depth -> MaxPool ->
Dense`` classifier with Lemma-1-constrained convolutions, plus the
per-layer differential operators (vjp/vijp) the Rust Moonwalk engine
executes via PJRT. The forward ops call the Pallas kernels so they lower
into the very HLO the Rust side loads (L1 -> L2 -> L3 composition).
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import pallas_kernels as K
from .kernels import ref


@dataclass
class ModelConfig:
    """Flagship e2e configuration (kept CPU-interpretable)."""

    batch: int = 8
    hw: int = 16
    cin: int = 3
    channels: int = 16
    depth: int = 2
    classes: int = 4
    alpha: float = 0.1
    k: int = 3
    stride: int = 2
    pad: int = 1
    seed: int = 0

    def spatial_after(self, i):
        """Spatial size after i conv blocks."""
        s = self.hw
        for _ in range(i):
            s = (s + 2 * self.pad - self.k) // self.stride + 1
        return s

    def pool_window(self):
        return 2 if self.spatial_after(self.depth) % 2 == 0 else 1

    def dense_in(self):
        s = self.spatial_after(self.depth) // self.pool_window()
        return s * s * self.channels


def init_params(cfg: ModelConfig):
    """He init + Lemma-1 projection for every conv; dense head."""
    key = jax.random.PRNGKey(cfg.seed)
    params = {"convs": [], "dense_w": None, "dense_b": None}
    for i in range(cfg.depth):
        key, sub = jax.random.split(key)
        fan_in = cfg.k * cfg.k * cfg.channels
        w = jax.random.normal(sub, (cfg.k, cfg.k, cfg.channels, cfg.channels))
        w = w * (2.0 / fan_in) ** 0.5
        w = w.at[cfg.pad, cfg.pad].add(jnp.eye(cfg.channels))
        w = ref.project_submersive_2d(w, cfg.pad)
        params["convs"].append(w)
    key, sub = jax.random.split(key)
    params["dense_w"] = jax.random.normal(sub, (cfg.dense_in(), cfg.classes)) * (
        2.0 / cfg.dense_in()
    ) ** 0.5
    params["dense_b"] = jnp.zeros((cfg.classes,))
    return params


# ----------------------------------------------------------- layer pieces


def upsample(x, cout):
    """Channel replication (parameter-free entry layer)."""
    cin = x.shape[-1]
    reps = -(-cout // cin)
    return jnp.tile(x, (1,) * (x.ndim - 1) + (reps,))[..., :cout]


def maxpool(x, q):
    n, h, w, c = x.shape
    xr = x.reshape(n, h // q, q, w // q, q, c)
    return xr.max(axis=(2, 4))


def dense_fwd(x2d, w, b):
    return x2d @ w + b


def dense_vjp_in(g, w):
    return g @ w.T


def dense_vjp_w(x2d, g):
    return x2d.T @ g


def small_inverse(m):
    """Unrolled Gauss-Jordan inverse for small static matrices.

    ``jnp.linalg.solve`` lowers to a LAPACK typed-FFI custom-call that the
    image's xla_extension 0.5.1 cannot execute; this stays in pure HLO.
    """
    n = m.shape[0]
    aug = jnp.concatenate([m, jnp.eye(n, dtype=m.dtype)], axis=1)
    for col in range(n):
        pivot = aug[col, col]
        aug = aug.at[col].set(aug[col] / pivot)
        for row in range(n):
            if row != col:
                aug = aug.at[row].add(-aug[row, col] * aug[col])
    return aug[:, n:]


def dense_vijp(h2d, w):
    """Right-inverse cotangent push: h' = (h W)(W^T W)^-1."""
    gram = w.T @ w
    return (h2d @ w) @ small_inverse(gram)


def loss_and_grad(logits, onehot):
    """Softmax cross-entropy value + gradient wrt logits."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    n = logits.shape[0]
    loss = -(onehot * logp).sum() / n
    g = (jax.nn.softmax(logits, axis=-1) - onehot) / n
    return loss, g


# ------------------------------------------------------------ full model


def forward(cfg: ModelConfig, params, x):
    """Full forward pass (calls the Pallas kernels for conv + lrelu)."""
    h = upsample(x, cfg.channels)
    for w in params["convs"]:
        h = K.conv2d_fwd(h, w, cfg.stride, cfg.pad)
        h = K.leaky_relu_fwd(h, cfg.alpha)
    q = cfg.pool_window()
    if q > 1:
        h = maxpool(h, q)
    h = h.reshape(h.shape[0], -1)
    return dense_fwd(h, params["dense_w"], params["dense_b"])


def loss_fn(cfg: ModelConfig, params, x, onehot):
    logits = forward(cfg, params, x)
    loss, _ = loss_and_grad(logits, onehot)
    return loss
