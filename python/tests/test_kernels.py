"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps
over shapes and geometries) — the core correctness signal of the compile
path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pallas_kernels as K
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


def submersive_w(key, k, cin, cout, pad):
    w = rand(key, (k, k, cin, cout), 0.3)
    w = w.at[pad, pad, : min(cin, cout), : min(cin, cout)].add(
        jnp.eye(min(cin, cout))
    )
    return ref.project_submersive_2d(w, pad)


# ------------------------------------------------------------ conv2d fwd


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.integers(5, 12),
    cin=st.integers(1, 5),
    cout=st.integers(1, 5),
    k=st.sampled_from([1, 3]),
    stride=st.integers(1, 2),
    seed=st.integers(0, 10_000),
)
def test_conv2d_fwd_matches_lax(n, hw, cin, cout, k, stride, seed):
    pad = k // 2
    if hw + 2 * pad < k:
        return
    x = rand(seed, (n, hw, hw, cin))
    w = rand(seed + 1, (k, k, cin, cout), 0.3)
    got = K.conv2d_fwd(x, w, stride, pad)
    want = ref.conv2d(x, w, stride, pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------- conv2d vijp


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 2),
    hw=st.integers(7, 13),
    cin=st.integers(2, 6),
    seed=st.integers(0, 10_000),
    cout_delta=st.integers(0, 2),
)
def test_conv2d_vijp_right_inverse(n, hw, cin, seed, cout_delta):
    """THE Moonwalk property: vijp(vjp(h')) == h' for the paper's
    k=3, s=2, p=1 fully-parallel configuration, including Cout < Cin."""
    cout = max(1, cin - cout_delta)
    k, stride, pad = 3, 2, 1
    w = submersive_w(seed, k, cin, cout, pad)
    ho = (hw + 2 * pad - k) // stride + 1
    hp = rand(seed + 2, (n, ho, ho, cout))
    h = ref.conv2d_vjp_input(hp, w, (n, hw, hw, cin), stride, pad)
    rec = K.conv2d_vijp(h, w, stride, pad)
    np.testing.assert_allclose(rec, hp, rtol=2e-3, atol=2e-4)


def test_conv2d_vijp_matches_ref_impl():
    w = submersive_w(7, 3, 4, 4, 1)
    h = rand(8, (2, 9, 9, 4))
    got = K.conv2d_vijp(h, w, 2, 1)
    want = ref.conv2d_vijp_fast(h, w, 2, 1, (5, 5))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_conv2d_vijp_agrees_with_lstsq_oracle():
    """Tiny-shape brute force: the elimination equals the least-squares
    right-inverse on the row space (uniqueness claim of §4.2)."""
    w = submersive_w(11, 3, 2, 2, 1)
    x_shape = (1, 5, 5, 2)
    out_shape = (1, 3, 3, 2)
    hp = rand(12, out_shape)
    h = ref.conv2d_vjp_input(hp, w, x_shape, 2, 1)
    got = K.conv2d_vijp(h, w, 2, 1)
    want = ref.conv2d_vijp_lstsq(h, w, x_shape, 2, 1, out_shape)
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-3)


def test_conv2d_vijp_rejects_bad_geometry():
    with pytest.raises(AssertionError):
        K.conv2d_vijp(jnp.zeros((1, 8, 8, 3)), jnp.zeros((5, 5, 3, 3)), 2, 1)


# ----------------------------------------------------- fragmental (1-D)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 2),
    n_blocks=st.integers(1, 4),
    block=st.sampled_from([4, 8, 16]),
    cin=st.integers(1, 5),
    k=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 10_000),
)
def test_fragment_reconstruct_roundtrip(n, n_blocks, block, cin, k, seed):
    """Alg. 3 recovers the exact output cotangent from (k-1)-slice
    fragments for random geometries."""
    if block < k:
        return
    ll = n_blocks * block
    w = rand(seed, (k, cin, cin), 0.3)
    w = w.at[0, : cin, : cin].add(jnp.eye(cin))
    w = ref.project_fragmental_1d(w)
    # hp has output length ll (choose input length so L' = ll: L = ll+k-3)
    lin = ll + k - 3
    if lin < k:
        return
    x_shape = (n, lin, cin)
    hp = rand(seed + 1, (n, ll, cin))
    h = ref.conv1d_vjp_input(hp, w, x_shape, 1, 1)
    frag = ref.conv1d_fragment_capture(hp, block, k)
    # fit h's spatial axis to exactly n_blocks*block rows for the kernel
    # (k=4 gives an input one longer than the output; k=2 one shorter)
    if h.shape[1] >= ll:
        hpad = h[:, :ll, :]
    else:
        hpad = jnp.pad(h, ((0, 0), (0, ll - h.shape[1]), (0, 0)))
    got = K.conv1d_fragment_reconstruct(hpad, frag, w, block)
    np.testing.assert_allclose(got, hp, rtol=5e-3, atol=5e-4)


def test_fragment_capture_sizes():
    hp = jnp.ones((2, 32, 8))
    frag = ref.conv1d_fragment_capture(hp, 4, 3)
    assert frag.shape == (2, 16, 8)  # 2 of every 4 slices
    frag16 = ref.conv1d_fragment_capture(hp, 16, 3)
    assert frag16.shape == (2, 4, 8)  # 1/8 of full


# ------------------------------------------------------------ leaky relu


@settings(max_examples=15, deadline=None)
@given(
    shape=st.sampled_from([(4,), (2, 3), (2, 4, 4, 3)]),
    alpha=st.sampled_from([0.01, 0.1, 0.5]),
    seed=st.integers(0, 10_000),
)
def test_leaky_relu_kernels(shape, alpha, seed):
    x = rand(seed, shape)
    g = rand(seed + 1, shape)
    np.testing.assert_allclose(
        K.leaky_relu_fwd(x, alpha), ref.leaky_relu(x, alpha), rtol=1e-6
    )
    np.testing.assert_allclose(
        K.leaky_relu_vjp(x, g, alpha), ref.leaky_relu_vjp(x, g, alpha), rtol=1e-6
    )
    # vijp inverts vjp exactly (diagonal Jacobian)
    h = ref.leaky_relu_vjp(x, g, alpha)
    np.testing.assert_allclose(
        K.leaky_relu_vijp(x, h, alpha), g, rtol=1e-4, atol=1e-6
    )


# --------------------------------------------------------- jax.grad check


def test_conv_vjp_refs_match_autodiff():
    x = rand(0, (2, 8, 8, 3))
    w = rand(1, (3, 3, 3, 4), 0.3)
    g = rand(2, (2, 4, 4, 4))
    loss = lambda x_, w_: (ref.conv2d(x_, w_, 2, 1) * g).sum()
    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(
        ref.conv2d_vjp_input(g, w, x.shape, 2, 1), gx, rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        ref.conv2d_vjp_w(x, g, w.shape, 2, 1), gw, rtol=1e-4, atol=1e-5
    )
