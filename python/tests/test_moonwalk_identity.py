"""L2 cross-check: the Moonwalk identity (Eq. 7) against jax.grad, with
the forward sweep running the Pallas vijp kernel (Alg. 2)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import moonwalk_jax as MW
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_stack(depth, ch, seed):
    ws = []
    for i in range(depth):
        w = jax.random.normal(jax.random.PRNGKey(seed + i), (3, 3, ch, ch)) * 0.25
        w = w.at[1, 1].add(jnp.eye(ch))
        ws.append(ref.project_submersive_2d(w, 1))
    return ws


@settings(max_examples=8, deadline=None)
@given(
    depth=st.integers(1, 4),
    ch=st.integers(2, 6),
    hw=st.sampled_from([9, 13, 17]),
    seed=st.integers(0, 1000),
)
def test_moonwalk_equals_backprop(depth, ch, hw, seed):
    ws = make_stack(depth, ch, seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 99), (2, hw, hw, ch))
    g_bp = MW.grads_backprop(ws, x, 2, 1, 0.1)
    g_mw = MW.grads_moonwalk(ws, x, 2, 1, 0.1)
    for a, b in zip(g_bp, g_mw):
        scale = float(jnp.max(jnp.abs(a))) + 1e-8
        np.testing.assert_allclose(
            np.asarray(b) / scale, np.asarray(a) / scale, rtol=0, atol=5e-5
        )


def test_moonwalk_model_forward_runs():
    """Flagship model forward (with Pallas kernels) produces finite
    logits of the right shape."""
    from compile import model as M

    cfg = M.ModelConfig(batch=2, hw=16, channels=8, depth=2)
    params = M.init_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 3))
    logits = M.forward(cfg, params, x)
    assert logits.shape == (2, cfg.classes)
    assert bool(jnp.isfinite(logits).all())


def test_dense_vijp_right_inverse():
    from compile import model as M

    w = jax.random.normal(jax.random.PRNGKey(1), (12, 4))
    hp = jax.random.normal(jax.random.PRNGKey(2), (3, 4))
    h = M.dense_vjp_in(hp, w)  # h = hp W^T (input cotangent)
    rec = M.dense_vijp(h, w)
    np.testing.assert_allclose(rec, hp, rtol=1e-3, atol=1e-4)
