"""AOT path sanity: ops lower to parseable HLO text with consistent
manifest shapes (the Rust-side round trip is rust/tests/runtime_pjrt.rs)."""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def test_lower_op_produces_hlo_text():
    fn = lambda a, b: (a @ b,)
    hlo, outs = aot.lower_op(fn, [aot.spec(2, 3), aot.spec(3, 4)])
    assert "HloModule" in hlo
    assert outs == [[2, 4]]


def test_build_ops_cover_all_layers():
    cfg = M.ModelConfig(batch=2, hw=16, channels=4, depth=2)
    ops = aot.build_ops(cfg)
    for i in range(cfg.depth):
        for stem in ["conv{}_fwd", "conv{}_vjp_in", "conv{}_vjp_w", "conv{}_vijp",
                     "lrelu{}_fwd", "lrelu{}_vjp", "lrelu{}_vijp"]:
            assert stem.format(i) in ops
    for name in ["dense_fwd", "dense_vjp_in", "dense_vjp_w", "dense_vijp", "loss_grad"]:
        assert name in ops


def test_emit_writes_manifest(tmp_path):
    cfg = M.ModelConfig(batch=1, hw=8, channels=2, depth=1, classes=2)
    aot.emit(str(tmp_path), cfg)
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["config"]["depth"] == 1
    assert len(manifest["ops"]) == 12
    for op in manifest["ops"]:
        path = tmp_path / op["file"]
        assert path.exists()
        text = path.read_text()
        assert "HloModule" in text
        assert op["inputs"], op["name"]
